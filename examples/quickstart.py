"""Quickstart: build a SearchEngine over a few documents and run every query
type through the one facade — AND / OR, DR / DRB / auto, tf-idf / BM25 —
then recover snippets straight from the compressed index.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.engine import SearchEngine
from repro.text import vocab

DOCS = [
    "ranked document retrieval in almost no space".split(),
    "the wavelet tree on bytecodes rearranges compressed text".split(),
    "dense codes give fast byte oriented decompression".split(),
    "ranked retrieval needs only rank and select on bytes".split(),
    "the quick brown fox jumps over the lazy dog".split(),
    "wavelet trees support ranked retrieval in compressed space space space".split(),
]


def main():
    v = vocab.Vocabulary.from_documents(DOCS)
    engine = SearchEngine.build(v.encode_docs(DOCS), vocab_size=v.size)

    def ids(*ws):
        return [v.id_of(w) for w in ws]

    print("== AND query: 'ranked retrieval' (DR — no extra space) ==")
    res = engine.search([ids("ranked", "retrieval")], k=3, mode="and",
                        strategy="dr")
    for d, s in res.hits(0):
        print(f"  doc {d} (tf-idf {s:.2f}): {' '.join(DOCS[d])}")

    print("== OR query, BM25: 'space fox' (auto-routed to DRB) ==")
    res = engine.search([ids("space", "fox")], k=3, mode="or", measure="bm25")
    for d, s in res.hits(0):
        print(f"  doc {d} (bm25 {s:.2f}): {' '.join(DOCS[d])}")

    print("== snippet extraction from the compressed text ==")
    for hit, snippet in zip(res.hits(0), engine.snippets(res, length=5)[0]):
        words = " ".join(v.words[int(w)] for w in snippet)
        print(f"  doc {hit[0]}: {words} ...")

    rep = engine.space_report()
    print(f"== space == total index bytes: {rep['total']} "
          f"(byte stream {rep['level_bytes']}, counters {rep['rank_counters']})")


if __name__ == "__main__":
    main()
