"""Quickstart: build a WTBC over a few documents and run every query type.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import drb, ranked, scoring, wtbc
from repro.text import vocab

DOCS = [
    "ranked document retrieval in almost no space".split(),
    "the wavelet tree on bytecodes rearranges compressed text".split(),
    "dense codes give fast byte oriented decompression".split(),
    "ranked retrieval needs only rank and select on bytes".split(),
    "the quick brown fox jumps over the lazy dog".split(),
    "wavelet trees support ranked retrieval in compressed space space space".split(),
]


def main():
    v = vocab.Vocabulary.from_documents(DOCS)
    idx, model = wtbc.build_index(v.encode_docs(DOCS), v.size, block=256)
    aux = drb.build_aux(idx, model, v.encode_docs(DOCS))
    measure = scoring.TfIdf()
    idf = measure.idf(idx)

    def q(*ws):
        ranks = model.rank_of_word[[v.id_of(w) for w in ws]]
        return jnp.asarray(ranks, jnp.int32), jnp.ones(len(ws), bool)

    words, wmask = q("ranked", "retrieval")
    print("== AND query: 'ranked retrieval' ==")
    res = ranked.topk_dr(idx, words, wmask, idf, k=3, conjunctive=True,
                         heap_cap=2 * len(DOCS) + 4)
    for d, s in zip(np.asarray(res.docs), np.asarray(res.scores)):
        if d >= 0:
            print(f"  doc {d} (tf-idf {s:.2f}): {' '.join(DOCS[d])}")

    print("== OR query via DRB, BM25: 'space fox' ==")
    words, wmask = q("space", "fox")
    res = drb.topk_drb_or(idx, aux, words, wmask, scoring.BM25(), k=3,
                          max_df_cap=8)
    for d, s in zip(np.asarray(res.docs), np.asarray(res.scores)):
        if d >= 0:
            print(f"  doc {d} (bm25 {s:.2f}): {' '.join(DOCS[d])}")

    print("== snippet extraction from the compressed text ==")
    w = int(model.rank_of_word[v.id_of("fox")])
    p = int(wtbc.locate(idx, jnp.int32(w), jnp.int32(1)))
    snippet = np.asarray(wtbc.extract(idx, jnp.int32(p - 2), 5))
    print("  ...", " ".join(v.words[int(model.word_of_rank[r])] for r in snippet), "...")

    rep = wtbc.space_report(idx)
    print(f"== space == total index bytes: {rep['total']} "
          f"(byte stream {rep['level_bytes']}, counters {rep['rank_counters']})")


if __name__ == "__main__":
    main()
