"""End-to-end driver: a small in-memory search engine serving batched ranked
queries over a synthetic corpus (the paper's deployment, scaled to CPU).

    PYTHONPATH=src python examples/search_engine.py --docs 2000 --batch 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drb, ranked, scoring, wtbc
from repro.text import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--vocab", type=int, default=20000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    t0 = time.time()
    cp = corpus.make_corpus(n_docs=args.docs, mean_doc_len=200,
                            vocab_size=args.vocab, seed=0)
    idx, model = wtbc.build_index(cp.doc_tokens, cp.vocab_size)
    aux = drb.build_aux(idx, model, cp.doc_tokens)
    print(f"indexed {cp.n_tokens} tokens / {cp.n_docs} docs "
          f"in {time.time()-t0:.1f}s")
    rep = wtbc.space_report(idx)
    print(f"index bytes: {rep['total']:,} "
          f"({rep['total']/cp.n_tokens:.2f} B/token)")

    measure = scoring.TfIdf()
    idf = measure.idf(idx)
    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    queries = corpus.sample_queries(df, bands["ii"], args.batch, 3, seed=1)
    words = jnp.asarray(model.rank_of_word[queries], jnp.int32)
    wmask = jnp.ones_like(words, dtype=bool)
    heap_cap = 2 * int(idx.n_docs) + 4

    for name, fn in [
        ("DR/AND", lambda: ranked.topk_dr_batch(idx, words, wmask, idf,
                                                k=args.k, conjunctive=True,
                                                heap_cap=heap_cap)),
        ("DR/OR", lambda: ranked.topk_dr_batch(idx, words, wmask, idf,
                                               k=args.k, conjunctive=False,
                                               heap_cap=heap_cap)),
        ("DRB/AND", lambda: jax.vmap(
            lambda w, m: drb.topk_drb_and(idx, aux, w, m, measure, k=args.k)
        )(words, wmask)),
    ]:
        jax.block_until_ready(fn())                # compile
        t0 = time.time()
        res = jax.block_until_ready(fn())
        dt = (time.time() - t0) / args.batch * 1e3
        print(f"{name:8s} {dt:7.2f} ms/query | "
              f"top doc of q0: {int(np.asarray(res.docs)[0, 0])}")


if __name__ == "__main__":
    main()
