"""End-to-end driver: a small in-memory search engine serving batched ranked
queries over a synthetic corpus (the paper's deployment, scaled to CPU), all
through the unified `repro.engine.SearchEngine` facade — one build call, one
``search`` call per workload shape.

    PYTHONPATH=src python examples/search_engine.py --docs 2000 --batch 32
"""
import argparse
import time

import jax
import numpy as np

from repro.engine import SearchEngine
from repro.text import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--vocab", type=int, default=20000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    t0 = time.time()
    cp = corpus.make_corpus(n_docs=args.docs, mean_doc_len=200,
                            vocab_size=args.vocab, seed=0)
    engine = SearchEngine.build(cp)
    print(f"indexed {cp.n_tokens} tokens / {cp.n_docs} docs "
          f"in {time.time()-t0:.1f}s")
    rep = engine.space_report()
    print(f"index bytes: {rep['total']:,} "
          f"({rep['total']/cp.n_tokens:.2f} B/token)")

    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    queries = corpus.sample_queries(df, bands["ii"], args.batch, 3, seed=1)
    # positional workloads: bigrams lifted from the documents themselves, so
    # phrase queries actually have occurrences to rank
    grams = corpus.sample_ngram_queries(cp.doc_tokens, args.batch, 2, seed=2)

    for name, qs, kw in [
        ("DR/AND", queries, dict(mode="and", strategy="dr")),
        ("DR/OR", queries, dict(mode="or", strategy="dr")),
        ("DR/OR·16", queries, dict(mode="or", strategy="dr", beam_width=16)),
        ("DRB/AND", queries, dict(mode="and", strategy="drb")),
        ("BM25/OR", queries, dict(mode="or", strategy="auto", measure="bm25")),
        ("PHRASE", grams, dict(mode="phrase")),
        ("NEAR/8", grams, dict(mode="near", window=8)),
    ]:
        run = lambda: engine.search(qs, k=args.k, **kw)
        jax.block_until_ready(run().scores)        # compile
        t0 = time.time()
        res = run()
        jax.block_until_ready(res.scores)
        dt = (time.time() - t0) / args.batch * 1e3
        extra = ""
        if res.beam_width > 1:
            d = res.diagnostics
            extra = (f" | beam {res.beam_width}: {int(np.sum(d['work']))} "
                     f"trips / {int(np.sum(d['pops']))} pops")
        if res.match_pos is not None:
            m = res.matches(0)
            if m:
                d, _, p, l = m[0]
                extra = f" | q0 match: doc {d} @ {p} width {l}"
        print(f"{name:8s} {dt:7.2f} ms/query | "
              f"top doc of q0: {int(np.asarray(res.docs)[0, 0])}{extra}")
    print(f"executor cache: {engine.stats['executors']} compiled programs")


if __name__ == "__main__":
    main()
