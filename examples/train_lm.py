"""Train a reduced-config LM (same family as the assigned archs) for a few
hundred steps on CPU with checkpoint/restart enabled.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 200
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=200)
    args, _ = ap.parse_known_args()
    sys.argv = ["train", "--arch", args.arch, "--smoke",
                "--steps", str(args.steps), "--batch", "8", "--seq", "64",
                "--ckpt-every", "50"]
    train.main()


if __name__ == "__main__":
    main()
