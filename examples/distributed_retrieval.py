"""Document-sharded distributed retrieval over 8 simulated devices.

    PYTHONPATH=src python examples/distributed_retrieval.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed, scoring
from repro.text import corpus


def main():
    cp = corpus.make_corpus(n_docs=2000, mean_doc_len=150, vocab_size=20000,
                            seed=0)
    t0 = time.time()
    sharded, model = distributed.build_sharded(cp.doc_tokens, cp.vocab_size,
                                               n_shards=8)
    print(f"built 8 shards in {time.time()-t0:.1f}s "
          f"({cp.n_tokens} tokens, global (s,c)=({model.s},{model.c}))")

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shards",))
    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    queries = corpus.sample_queries(df, bands["ii"], 16, 3, seed=2)
    words = jnp.asarray(model.rank_of_word[queries], jnp.int32)
    wmask = jnp.ones_like(words, dtype=bool)

    for method in ("dr-or", "dr-and", "drb-and"):
        fn = lambda: distributed.distributed_topk(
            sharded, words, wmask, k=10, method=method, mesh=mesh,
            shard_axes="shards", max_df_cap=256)
        jax.block_until_ready(fn())
        t0 = time.time()
        res = jax.block_until_ready(fn())
        dt = (time.time() - t0) / 16 * 1e3
        print(f"{method:8s} {dt:7.2f} ms/query | global top doc q0: "
              f"{int(np.asarray(res.docs)[0, 0])} | shard pops: {int(res.iters[0]) if res.iters.ndim else int(res.iters)}")


if __name__ == "__main__":
    main()
