"""Document-sharded distributed retrieval over 8 simulated devices, served
through `repro.engine.SearchEngine.shard` — the facade owns the mesh, the
shard merge, and the jitted executor cache.

    PYTHONPATH=src python examples/distributed_retrieval.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.engine import SearchEngine
from repro.text import corpus


def main():
    cp = corpus.make_corpus(n_docs=2000, mean_doc_len=150, vocab_size=20000,
                            seed=0)
    t0 = time.time()
    engine = SearchEngine.shard(cp, n_shards=8)
    print(f"built 8 shards in {time.time()-t0:.1f}s "
          f"({cp.n_tokens} tokens, global (s,c)=({engine.model.s},{engine.model.c}))")

    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    queries = corpus.sample_queries(df, bands["ii"], 16, 3, seed=2)

    for mode, strategy in (("or", "dr"), ("and", "dr"), ("and", "drb")):
        run = lambda: engine.search(queries, k=10, mode=mode, strategy=strategy)
        jax.block_until_ready(run().scores)
        t0 = time.time()
        res = run()
        jax.block_until_ready(res.scores)
        dt = (time.time() - t0) / 16 * 1e3
        print(f"{strategy}-{mode:4s} {dt:7.2f} ms/query | global top doc q0: "
              f"{int(np.asarray(res.docs)[0, 0])} | shard pops: "
              f"{int(np.asarray(res.work)[0])}")


if __name__ == "__main__":
    main()
