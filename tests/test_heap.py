"""Array-heap invariants (the engine under Algorithm 1)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import heap as H


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, allow_subnormal=False,
                          width=32),
                min_size=1, max_size=24))
def test_push_pop_sorts_descending(xs):
    h = H.make(len(xs) + 4, 1)
    for i, x in enumerate(xs):
        h = H.push(h, jnp.float32(x), jnp.array([i], jnp.int32))
    out = []
    for _ in range(len(xs)):
        s, p, h = H.pop(h)
        out.append(float(s))
    assert out == sorted(map(np.float32, xs), reverse=True)
    assert int(h.size) == 0


def test_disabled_push_is_noop():
    h = H.make(8, 1)
    h = H.push(h, jnp.float32(5.0), jnp.array([1], jnp.int32))
    h = H.push(h, jnp.float32(9.0), jnp.array([2], jnp.int32), enable=False)
    assert int(h.size) == 1
    s, p, h = H.pop(h)
    assert float(s) == 5.0 and int(p[0]) == 1


def test_push_beyond_capacity_drops():
    h = H.make(2, 1)
    for i in range(5):
        h = H.push(h, jnp.float32(i), jnp.array([i], jnp.int32))
    assert int(h.size) == 2


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.floats(-100, 100, allow_nan=False,
                                    allow_subnormal=False, width=32),
                          st.integers(0, 1000)), min_size=1, max_size=24),
       st.integers(1, 8))
def test_bounded_topk(pairs, k):
    t = H.topk_make(k)
    for s, d in pairs:
        t = H.topk_insert(t, jnp.float32(s), jnp.int32(d))
    t = H.topk_sorted(t)
    got = [float(x) for x in t.scores if x > -np.inf]
    want = sorted([np.float32(s) for s, _ in pairs], reverse=True)[:k]
    # the bounded structure keeps the k best scores
    assert got == sorted(want, reverse=True)[: len(got)]
    assert len(got) == min(k, len(pairs))
