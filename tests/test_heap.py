"""Array-heap invariants (the engine under Algorithm 1)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import heap as H


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, allow_subnormal=False,
                          width=32),
                min_size=1, max_size=24))
def test_push_pop_sorts_descending(xs):
    h = H.make(len(xs) + 4, 1)
    for i, x in enumerate(xs):
        h = H.push(h, jnp.float32(x), jnp.array([i], jnp.int32))
    out = []
    for _ in range(len(xs)):
        s, p, h = H.pop(h)
        out.append(float(s))
    assert out == sorted(map(np.float32, xs), reverse=True)
    assert int(h.size) == 0


def test_disabled_push_is_noop():
    h = H.make(8, 1)
    h = H.push(h, jnp.float32(5.0), jnp.array([1], jnp.int32))
    h = H.push(h, jnp.float32(9.0), jnp.array([2], jnp.int32), enable=False)
    assert int(h.size) == 1
    s, p, h = H.pop(h)
    assert float(s) == 5.0 and int(p[0]) == 1


def test_push_beyond_capacity_drops():
    h = H.make(2, 1)
    for i in range(5):
        h = H.push(h, jnp.float32(i), jnp.array([i], jnp.int32))
    assert int(h.size) == 2


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.floats(-100, 100, allow_nan=False,
                                    allow_subnormal=False, width=32),
                          st.integers(0, 1000)), min_size=1, max_size=24),
       st.integers(1, 8))
def test_bounded_topk(pairs, k):
    t = H.topk_make(k)
    for s, d in pairs:
        t = H.topk_insert(t, jnp.float32(s), jnp.int32(d))
    t = H.topk_sorted(t)
    got = [float(x) for x in t.scores if x > -np.inf]
    want = sorted([np.float32(s) for s, _ in pairs], reverse=True)[:k]
    # the bounded structure keeps the k best scores
    assert got == sorted(want, reverse=True)[: len(got)]
    assert len(got) == min(k, len(pairs))


# ---------------------------------------------------------------------------
# total lex order (score desc, d0 asc, d1 desc) — DESIGN.md §8
# ---------------------------------------------------------------------------

def _drain(h):
    out = []
    while int(h.size) > 0:
        s, p, h = H.pop(h)
        out.append((float(s), int(p[0]), int(p[1])))
    return out


def test_pop_p_tie_break_follows_total_order():
    """pop_p drains score ties by (d0 asc, d1 desc) — the same flattened
    sequence at any P, whatever order the pushes arrived in."""
    entries = [(2.0, 5, 9), (2.0, 1, 9), (2.0, 1, 30), (3.0, 7, 8),
               (2.0, 5, 12)]
    expect = sorted(entries, key=lambda e: (-e[0], e[1], -e[2]))
    for order in (entries, entries[::-1]):
        h = H.make(16, 2)
        for s, d0, d1 in order:
            h = H.push(h, jnp.float32(s), jnp.array([d0, d1], jnp.int32))
        ss, pp, vv, h = H.pop_p(h, 5)
        got = [(float(s), int(p[0]), int(p[1]))
               for s, p, v in zip(np.asarray(ss), np.asarray(pp),
                                  np.asarray(vv)) if v]
        assert got == expect
        assert int(h.size) == 0


def test_push_many_all_equal_scores_pops_by_payload():
    """Degenerate bulk insert — every score identical: pop order falls
    entirely to the payload key, independent of the array order pushed."""
    pays = np.array([[3, 9], [0, 9], [0, 40], [2, 9], [1, 9]], np.int32)
    expect = [(1.0, 0, 40), (1.0, 0, 9), (1.0, 1, 9), (1.0, 2, 9),
              (1.0, 3, 9)]
    for perm in (np.arange(5), np.arange(5)[::-1]):
        h = H.make(12, 2)
        h = H.push_many(h, jnp.ones(5, jnp.float32), jnp.asarray(pays[perm]),
                        jnp.ones(5, bool))
        assert _drain(h) == expect


def test_push_many_overflow_latches_and_keeps_best():
    """Bulk pushes past capacity drop elements but LATCH ``overflowed`` —
    the signal SearchResults.diagnostics surfaces to callers."""
    h = H.make(3, 2)
    scores = jnp.asarray(np.array([5.0, 4.0, 3.0, 2.0, 1.0], np.float32))
    pays = jnp.asarray(np.arange(10, dtype=np.int32).reshape(5, 2))
    enable = jnp.ones(5, bool)
    h = H.push_many(h, scores, pays, enable)
    assert bool(h.overflowed)
    assert int(h.size) == 3
    # disabled pushes against a full heap must NOT latch
    h2 = H.make(3, 2)
    h2 = H.push_many(h2, scores, pays,
                     jnp.asarray(np.array([1, 1, 1, 0, 0], bool)))
    assert not bool(h2.overflowed)
    assert [s for s, _, _ in _drain(h)] == [5.0, 4.0, 3.0]
