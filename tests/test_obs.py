"""repro.obs — observability subsystem contract tests (DESIGN.md §10).

Pins, in order of importance:

* **bitwise neutrality** — enabling the registry must not change any ranked
  answer (observation happens on host copies after device values exist);
* **disabled is free** — with the registry off, no timeline is allocated,
  no observation lands, and a recording call is a cheap checked no-op;
* **histogram exactness** — percentile reconstruction is exact for integer
  observations below 2*SUBBUCKETS and within 1/SUBBUCKETS relative error
  elsewhere; p0/p100 are the tracked exact extremes;
* **diagnostics threading** — DRResult.padded/overflowed reach
  SearchResults -> RowResult -> server stats/registry on the plain, mega,
  and sharded paths;
* **stats under concurrency** — SearchServer.stats is safe to hammer while
  traffic flows and never blends two engines across swap_engine.
"""
import json
import threading
import time
import types
import urllib.request

import numpy as np
import pytest

import repro.obs as obs
from repro.engine import EngineConfig, SearchEngine
from repro.obs.metrics import SUBBUCKETS, bucket_hi, bucket_lo
from repro.obs.tracing import Timeline, stage_durations
from repro.serve import QueryProfile, SearchServer, loadgen
from repro.serve.server import RowResult, _slice_rows
from repro.text import corpus


@pytest.fixture(scope="module")
def obs_corpus():
    return corpus.make_corpus(n_docs=100, mean_doc_len=50, vocab_size=400,
                              seed=21)


@pytest.fixture(scope="module")
def obs_engine(obs_corpus):
    return SearchEngine.build(obs_corpus, EngineConfig(block=512))


@pytest.fixture(scope="module")
def obs_queries(obs_engine):
    return loadgen.sample_queries(obs_engine, 16, 3, seed=5)


# ---------------------------------------------------------------------------
# metrics: histogram exactness + primitives
# ---------------------------------------------------------------------------

def test_histogram_exact_for_small_integers():
    """Integer observations < 2*SUBBUCKETS live in width-<=1 buckets, so
    nearest-rank reconstruction equals numpy's inverted_cdf exactly — the
    'exact p50/p95/p99' claim for work counters and batch sizes."""
    rng = np.random.default_rng(0)
    reg = obs.Registry(enabled=True)
    h = reg.histogram("work")
    vals = rng.integers(1, 2 * SUBBUCKETS, size=2000)
    h.observe_many(vals.tolist())
    for q in (1, 25, 50, 75, 95, 99):
        want = float(np.percentile(vals, q, method="inverted_cdf"))
        assert h.quantile(q) == want, q


def test_histogram_relative_error_bound():
    rng = np.random.default_rng(1)
    reg = obs.Registry(enabled=True)
    h = reg.histogram("lat")
    vals = rng.lognormal(mean=-5.0, sigma=2.0, size=5000)
    h.observe_many(vals.tolist())
    for q in (50, 90, 95, 99):
        want = float(np.percentile(vals, q, method="inverted_cdf"))
        got = h.quantile(q)
        assert got <= want                        # bucket LOWER bound
        assert (want - got) / want <= 1.0 / SUBBUCKETS + 1e-12, q


def test_histogram_extremes_zeros_and_buckets():
    reg = obs.Registry(enabled=True)
    h = reg.histogram("h")
    h.observe_many([0.0, 0.0, 0.25, 3.0, 1000.0])
    assert h.quantile(0) == 0.0 and h.quantile(100) == 1000.0   # exact min/max
    assert h.quantile(30) == 0.0                  # zeros bucket
    assert h.n == 5 and h.n_zero == 2
    assert h.mean == pytest.approx((0.25 + 3.0 + 1000.0) / 5)
    # bucket geometry: lo/hi bracket every value, width = 2^e / SUBBUCKETS
    for v in (0.25, 3.0, 1000.0, 1e-9, 7.99):
        from repro.obs.metrics import bucket_index
        i = bucket_index(v)
        assert bucket_lo(i) <= v < bucket_hi(i), v


def test_registry_disabled_records_nothing():
    reg = obs.Registry(enabled=False)
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(5), g.set(3.0), h.observe(1.0)
    assert c.value == 0 and g.value == 0.0 and h.n == 0
    reg.enabled = True
    c.inc(5), g.set(3.0), h.observe(1.0)
    assert c.value == 5 and g.value == 3.0 and h.n == 1


def test_registry_get_or_create_and_kind_guard():
    reg = obs.Registry(enabled=True)
    assert reg.counter("x", {"a": "1"}) is reg.counter("x", {"a": "1"})
    assert reg.counter("x", {"a": "1"}) is not reg.counter("x", {"a": "2"})
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x", {"a": "1"})


def test_default_registry_enable_and_use():
    assert obs.default_registry().enabled is False     # process default: off
    mine = obs.Registry(enabled=True)
    with obs.use(mine):
        assert obs.default_registry() is mine
        obs.default_registry().counter("k").inc()
    assert obs.default_registry() is not mine
    assert mine.counter("k").value == 1


def test_disabled_recording_is_cheap():
    """The disabled path is one attr load + branch — pin a generous ceiling
    so a lock/allocation sneaking in fails loudly (DESIGN.md §10 budget)."""
    reg = obs.Registry(enabled=False)
    c, h = reg.counter("c"), reg.histogram("h")
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
        h.observe(1.0)
    per_call_us = (time.perf_counter() - t0) / (2 * n) * 1e6
    assert per_call_us < 5.0, f"{per_call_us:.2f}us per disabled record"


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_timeline_spans_and_stage_durations():
    tl = Timeline(100.0)
    for stage, t in (("admit", 100.5), ("lane_enqueue", 100.6),
                     ("batch_form", 101.0), ("dispatch", 101.5),
                     ("device", 103.5), ("slice", 103.6), ("complete", 103.7)):
        tl.mark(stage, t)
    d = stage_durations(tl)
    assert d["queue_wait"] == pytest.approx(1.5)       # submit -> dispatch
    assert d["device"] == pytest.approx(2.0)           # dispatch -> device
    assert d["slice"] == pytest.approx(0.1)
    assert d["total"] == pytest.approx(3.7)
    # partial timelines (e.g. cache hit: no dispatch) drop missing stages
    tl2 = Timeline(0.0)
    tl2.mark("complete", 0.001)
    d2 = stage_durations(tl2)
    assert "device" not in d2 and d2["total"] == pytest.approx(0.001)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _filled_registry() -> obs.Registry:
    reg = obs.Registry(enabled=True)
    reg.counter("repro_c_total", {"x": "1"}, "a counter").inc(3)
    reg.gauge("repro_g", None, "a gauge").set(2.5)
    h = reg.histogram("repro_h_seconds", {"stage": "s"}, "a histogram")
    h.observe_many([0.0, 0.001, 0.002, 0.5, 3.0])
    return reg


def test_prometheus_rendering_parses_and_is_cumulative():
    text = obs.render_prometheus(_filled_registry())
    lines = [l for l in text.splitlines() if l and not l.startswith("#")]
    assert 'repro_c_total{x="1"} 3' in lines
    assert "repro_g 2.5" in lines
    buckets = []
    for l in lines:
        if l.startswith("repro_h_seconds_bucket"):
            le = l.split('le="')[1].split('"')[0]
            buckets.append((float("inf") if le == "+Inf" else float(le),
                            int(l.rsplit(" ", 1)[1])))
    assert buckets == sorted(buckets)          # le ascending, counts cumulative
    assert buckets[-1] == (float("inf"), 5)
    assert [c for _, c in buckets] == sorted(c for _, c in buckets)
    assert "repro_h_seconds_count" in text and "repro_h_seconds_sum" in text
    # every sample line parses as "name{labels} value"
    for l in lines:
        name_part, val = l.rsplit(" ", 1)
        float(val)
        assert name_part.startswith("repro_")


def test_jsonl_snapshot_roundtrip(tmp_path):
    reg = _filled_registry()
    line = obs.snapshot_line(reg)
    d = json.loads(line)
    assert d["metrics"]['repro_c_total{x="1"}'] == 3
    assert d["metrics"]['repro_h_seconds{stage="s"}']["count"] == 5
    p = tmp_path / "m.jsonl"
    obs.write_jsonl(p, reg)
    obs.write_jsonl(p, reg)
    assert len(p.read_text().splitlines()) == 2
    snap = obs.dump(reg, p)
    assert snap == reg.snapshot()
    assert len(p.read_text().splitlines()) == 3


def test_metrics_http_server_scrape():
    reg = _filled_registry()
    with obs.MetricsServer(reg, port=0) as srv:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=10).read().decode()
        assert 'repro_c_total{x="1"} 3' in body
        j = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics.json", timeout=10).read())
        assert j["metrics"]["repro_g"] == 2.5
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=10)


# ---------------------------------------------------------------------------
# serving integration: spans, stage histograms, neutrality, overhead
# ---------------------------------------------------------------------------

def _dummy_engine(delay_s: float = 0.0, padded=None):
    def search(queries, **kw):
        if delay_s:
            time.sleep(delay_s)
        B = len(queries)
        k = kw.get("k") or 3
        ns = types.SimpleNamespace(
            docs=np.tile(np.arange(k, dtype=np.int32), (B, 1)),
            scores=np.zeros((B, k), np.float32),
            n_found=np.full(B, k, np.int32), work=np.ones(B, np.int32),
            pops=None, overflowed=None, match_pos=None, match_len=None,
            k=k, mode=kw.get("mode", "and"), strategy="dr", measure="tfidf")
        if padded is not None:
            ns.padded = np.full(B, padded, np.int32)
        return ns
    return types.SimpleNamespace(
        search=search, model=types.SimpleNamespace(vocab_size=100),
        stats={"executors": 0, "traces": {}},
        warmup=lambda *a, **kw: 0)


def test_server_spans_and_stage_histograms_with_registry():
    reg = obs.Registry(enabled=True)
    eng = _dummy_engine(delay_s=0.002)
    with SearchServer(eng, max_batch=4, max_wait_ms=5.0, cache_size=16,
                      registry=reg) as server:
        tickets = [server.submit([1 + i % 7]) for i in range(12)]
        rows = [t.result(timeout=10.0) for t in tickets]
        hit = server.submit([1])               # replay -> cache-hit span
        hit.result(timeout=10.0)
    assert all(r.n_found == 3 for r in rows)
    # every dispatched ticket carries the full span taxonomy
    tl = tickets[0].timeline
    stages = [s for s, _ in tl.marks]
    assert stages[0] == "submit" and stages[-1] == "complete"
    for s in ("admit", "lane_enqueue", "batch_form", "dispatch", "device",
              "slice"):
        assert s in stages, s
    ts = [t for _, t in tl.marks]
    assert ts == sorted(ts)                    # marks are monotonic
    assert hit.cache_hit and hit.timeline is not None
    # the ticket's decomposition is exact: queue_wait + service == latency
    for t in tickets:
        assert t.queue_wait_s + t.service_s == pytest.approx(t.latency_s)
    # registry: stage histograms saw every dispatched request, counters agree
    by_stage = {dict(h.labels)["stage"]: h
                for h in reg.find("repro_request_stage_seconds")}
    assert by_stage["device"].n == 12
    assert by_stage["total"].n == 13           # cache hit records total too
    assert by_stage["queue_wait"].n == 12
    served = [c for c in reg.find("repro_server_requests_total")
              if dict(c.labels)["outcome"] == "served"][0]
    assert served.value == 13 == server.stats["served"]
    hits = reg.find("repro_cache_hits_total")[0]
    assert hits.value == 1 == server.stats["cache"]["hits"]
    assert reg.find("repro_batch_size")        # per-lane batch histogram
    assert reg.find("repro_dispatch_seconds")[0].n == \
        server.stats["dispatches"]


def test_server_disabled_registry_allocates_nothing():
    eng = _dummy_engine()
    reg = obs.Registry(enabled=False)
    with SearchServer(eng, max_batch=4, cache_size=0,
                      registry=reg) as server:
        t = server.submit([3])
        t.result(timeout=10.0)
    assert t.timeline is None                  # no span allocation when off
    for m in reg.metrics():
        v = m._snapshot()
        assert (v == 0 or v == 0.0 or
                (isinstance(v, dict) and v["count"] == 0)), m.name


def test_instrumentation_is_bitwise_neutral(obs_engine, obs_queries):
    """Identical queries with the registry off and on: every ranked leaf is
    bitwise equal — observation reads results, it never feeds back."""
    kw = dict(k=6, mode="or", strategy="dr")
    base = obs_engine.search(obs_queries[:4], **kw)
    reg = obs.Registry(enabled=True)
    with obs.use(reg):
        inst = obs_engine.search(obs_queries[:4], **kw)
    assert reg.find("repro_engine_searches_total")     # it DID record
    for name in ("docs", "scores", "n_found", "work", "pops"):
        np.testing.assert_array_equal(np.asarray(getattr(base, name)),
                                      np.asarray(getattr(inst, name)),
                                      err_msg=name)


def test_engine_records_work_and_roofline(obs_engine, obs_queries):
    reg = obs.Registry(enabled=True)
    with obs.use(reg):
        res = obs_engine.search(obs_queries[:3], k=5, mode="or",
                                strategy="dr")
    pops_h = reg.find("repro_engine_pops")[0]
    assert pops_h.n == 3
    assert pops_h.total == float(np.asarray(res.pops).sum())
    fracs = reg.find("repro_roofline_achieved_frac")
    assert fracs and 0.0 < fracs[0].value      # live gauge exported
    bpq = reg.find("repro_roofline_bytes_per_query")[0].value
    assert bpq > 0.0
    rows = [c for c in reg.find("repro_engine_rows_total")][0]
    assert rows.value == 3


# ---------------------------------------------------------------------------
# satellite 3: diagnostics threading (padded/overflowed end to end)
# ---------------------------------------------------------------------------

def test_slice_rows_threads_padded_per_row():
    res = types.SimpleNamespace(
        docs=np.zeros((3, 2), np.int32), scores=np.zeros((3, 2), np.float32),
        n_found=np.ones(3, np.int32), work=np.ones(3, np.int32),
        pops=np.array([4, 5, 6]), overflowed=np.array([False, True, False]),
        padded=np.array([0, 2, 7]), match_pos=None, match_len=None,
        k=2, mode="or", strategy="dr", measure="tfidf")
    rows = _slice_rows(res, 2)                 # pad row 2 dropped
    assert [r.padded for r in rows] == [0, 2]
    assert [r.overflowed for r in rows] == [False, True]
    assert [r.pops for r in rows] == [4, 5]
    # engines that report no padded diagnostics (dummy/legacy) -> None
    del res.padded
    assert all(r.padded is None for r in _slice_rows(res, 2))


def test_padded_threads_engine_to_server_stats(obs_engine, obs_queries):
    """DR beam search reports pad-waste; it must reach RowResult, the
    server's stats dict, and the registry counter un-mangled."""
    res = obs_engine.search(obs_queries[:2], k=5, mode="or", strategy="dr",
                            beam_width=4)
    assert res.padded is not None
    want = int(np.asarray(res.padded).sum())
    reg = obs.Registry(enabled=True)
    profile = QueryProfile(mode="or", strategy="dr", k=5, beam_width=4)
    with SearchServer(obs_engine, max_batch=2, max_wait_ms=50.0,
                      cache_size=0, registry=reg) as server:
        t0 = server.submit(obs_queries[0], profile)
        t1 = server.submit(obs_queries[1], profile)
        rows = [t0.result(timeout=60.0), t1.result(timeout=60.0)]
    got = [r.padded for r in rows]
    assert all(p is not None for p in got)
    # batched serving may batch the two rows together or not; either way the
    # per-row diagnostic sums match the direct batched search
    if server.stats["batch_hist"] == {2: 1}:
        assert got == [int(p) for p in np.asarray(res.padded)]
        assert server.stats["padded"] == want
    assert server.stats["padded"] == sum(got)
    assert reg.find("repro_server_padded_lanes_total")[0].value == sum(got)
    obs_engine.obs_registry = None             # unpin the module fixture


def test_diagnostics_thread_mega_path(obs_engine, obs_queries):
    """The pool-frontier megabatch core pops exactly one segment per live
    row per trip — zero pad lanes by construction — so ``padded`` is None
    end to end, while pops/overflowed still thread per row."""
    res = obs_engine.search(obs_queries[:3], k=5, mode="or", strategy="dr",
                            mega=True)
    assert res.padded is None and res.overflowed is not None
    assert res.pops is not None
    rows = _slice_rows(res, 3)
    assert all(r.padded is None for r in rows)
    assert [r.pops for r in rows] == [int(p) for p in np.asarray(res.pops)]
    assert [r.overflowed for r in rows] == \
        [bool(o) for o in np.asarray(res.overflowed)]
    # contrast: the lockstep beam path DOES report pad waste
    lock = obs_engine.search(obs_queries[:3], k=5, mode="or", strategy="dr",
                             beam_width=4)
    assert lock.padded is not None


@pytest.mark.slow
def test_padded_threads_sharded_path(obs_corpus):
    """n_shards=1 on the single CPU device: the sharded merge must psum and
    return padded (DR/DRB-AND), and report None only for DRB/OR."""
    eng = SearchEngine.shard(obs_corpus, n_shards=1,
                             config=EngineConfig(block=512))
    qs = loadgen.sample_queries(eng, 4, 2, seed=5)
    res = eng.search(qs, k=5, mode="or", strategy="dr", beam_width=2)
    assert res.padded is not None
    assert np.asarray(res.padded).shape == (4,)
    single = SearchEngine.build(obs_corpus, EngineConfig(block=512))
    sres = single.search(qs, k=5, mode="or", strategy="dr", beam_width=2)
    np.testing.assert_array_equal(np.asarray(res.padded),
                                  np.asarray(sres.padded))
    rows = _slice_rows(res, 4)
    assert all(r.padded is not None for r in rows)
    assert _slice_rows(eng.search(qs, k=5, mode="or", strategy="drb",
                                  measure="bm25"), 4)[0].padded is None


# ---------------------------------------------------------------------------
# satellite 1: stats under concurrency / across swap
# ---------------------------------------------------------------------------

def test_stats_safe_under_concurrent_traffic():
    eng = _dummy_engine(delay_s=0.001)
    errors = []
    with SearchServer(eng, max_batch=4, max_wait_ms=1.0, cache_size=8,
                      queue_depth=128) as server:
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                try:
                    st = server.stats
                    assert st["served"] <= st["submitted"]
                    assert set(st["cache"]) == {"hits", "misses", "hit_rate",
                                                "size", "capacity"}
                except Exception as e:          # pragma: no cover
                    errors.append(e)
                    return
        readers = [threading.Thread(target=reader) for _ in range(3)]
        for r in readers:
            r.start()
        tickets = [server.submit([1 + i % 9]) for i in range(60)]
        for t in tickets:
            t.result(timeout=10.0)
        stop.set()
        for r in readers:
            r.join()
    assert not errors
    assert server.stats["served"] == 60


def test_stats_never_blend_engines_across_swap():
    eng_a = _dummy_engine()
    eng_a.stats = {"executors": 1, "traces": {"a": 1}}
    eng_a.content_tag = 0xA
    eng_b = _dummy_engine()
    eng_b.stats = {"executors": 7, "traces": {"b": 3}}
    eng_b.content_tag = 0xB
    with SearchServer(eng_a, max_batch=2, cache_size=4) as server:
        server.submit([1]).result(timeout=10.0)
        st = server.stats
        assert (st["executors"], st["traces"], st["engine_tag"]) == (1, 1, 0xA)
        server.swap_engine(eng_b)
        st = server.stats
        assert (st["executors"], st["traces"], st["engine_tag"]) == (7, 3, 0xB)
        assert st["swaps"] == 1
        server.submit([1]).result(timeout=10.0)     # still serves post-swap
    assert server.stats["served"] == 2


# ---------------------------------------------------------------------------
# loadgen: queue/service split (satellite 2)
# ---------------------------------------------------------------------------

def test_loadreport_splits_queue_and_service():
    eng = _dummy_engine(delay_s=0.005)
    with SearchServer(eng, max_batch=4, max_wait_ms=1.0,
                      cache_size=0) as server:
        rep = loadgen.closed_loop(server, [[1 + i % 9] for i in range(24)],
                                  n_workers=6)
    assert rep.n_ok == 24
    assert len(rep.queue_ms) == 24 and len(rep.service_ms) == 24
    for p in ("queue_p50_ms", "queue_p99_ms", "service_p50_ms",
              "service_p99_ms"):
        assert np.isfinite(getattr(rep, p)), p
    # service includes the 5ms engine sleep; queue wait is bounded by the
    # 1ms coalescing budget plus backlog
    assert rep.service_p50_ms >= 5.0
    assert "queue p50" in rep.summary() and "service p50" in rep.summary()
    # the decomposition is exact in aggregate: sum(total) == sum(q) + sum(s)
    assert rep.latencies_ms.sum() == pytest.approx(
        rep.queue_ms.sum() + rep.service_ms.sum(), rel=1e-9)
    assert rep.stages is None                  # registry off -> no breakdown


def test_loadreport_stage_breakdown_with_registry():
    reg = obs.Registry(enabled=True)
    eng = _dummy_engine(delay_s=0.002)
    with SearchServer(eng, max_batch=4, max_wait_ms=1.0, cache_size=0,
                      registry=reg) as server:
        rep = loadgen.open_loop(server, [[1 + i % 9] for i in range(20)],
                                target_qps=400.0, timeout_s=30.0)
    assert rep.n_ok == 20
    assert rep.stages is not None
    for s in ("queue_wait", "device", "slice", "total"):
        assert s in rep.stages
        assert rep.stages[s]["count"] > 0
        assert np.isfinite(rep.stages[s]["p99_ms"])
    assert rep.stages["total"]["count"] == 20
