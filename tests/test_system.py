"""End-to-end behaviour: a search engine built from text in, ranked docs out.

Covers the full pipeline the paper describes: tokenize -> fit (s,c)-DC ->
build WTBC (+DRB bitmaps) -> answer top-k AND/OR queries -> extract snippets
around hits — all from the compressed representation only.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import drb, ranked, scoring, wtbc
from repro.text import corpus, vocab


def build_engine():
    docs = [
        "the compressed index answers ranked queries fast".split(),
        "wavelet trees rearrange the bytes of dense codes".split(),
        "ranked retrieval with wavelet trees uses little space".split(),
        "inverted indexes use more space than compressed self indexes".split(),
        "the quick brown fox avoids information retrieval".split(),
        "space efficient ranked retrieval on wavelet trees trees trees".split(),
    ]
    v = vocab.Vocabulary.from_documents(docs)
    idx, model = wtbc.build_index(v.encode_docs(docs), v.size, block=256)
    aux = drb.build_aux(idx, model, v.encode_docs(docs))
    return docs, v, idx, model, aux


def test_end_to_end_and_query():
    docs, v, idx, model, aux = build_engine()
    measure = scoring.TfIdf()
    idf = measure.idf(idx)
    words = jnp.asarray(model.rank_of_word[[v.id_of("wavelet"), v.id_of("trees")]],
                        jnp.int32)
    wmask = jnp.ones(2, bool)
    res = ranked.topk_dr(idx, words, wmask, idf, k=3, conjunctive=True,
                         heap_cap=2 * len(docs) + 4)
    got = [int(d) for d in np.asarray(res.docs)[: int(res.n_found)]]
    # docs containing both: 1, 2, 5; doc 5 has tf(trees)=3 -> highest score
    assert set(got) == {1, 2, 5}
    assert got[0] == 5
    drb_res = drb.topk_drb_and(idx, aux, words, wmask, measure, k=3)
    assert set(int(d) for d in np.asarray(drb_res.docs)[:3]) == {1, 2, 5}


def test_end_to_end_or_query_and_snippet():
    docs, v, idx, model, aux = build_engine()
    measure = scoring.TfIdf()
    idf = measure.idf(idx)
    words = jnp.asarray(model.rank_of_word[[v.id_of("fox"), v.id_of("space")]],
                        jnp.int32)
    wmask = jnp.ones(2, bool)
    res = ranked.topk_dr(idx, words, wmask, idf, k=5, conjunctive=False,
                         heap_cap=2 * len(docs) + 4)
    got = {int(d) for d in np.asarray(res.docs)[: int(res.n_found)]}
    assert got == {2, 3, 4, 5}
    # snippet: locate the only occurrence of 'fox' and decode around it
    w_fox = int(model.rank_of_word[v.id_of("fox")])
    p = int(wtbc.locate(idx, jnp.int32(w_fox), jnp.int32(1)))
    snippet_ranks = np.asarray(wtbc.extract(idx, jnp.int32(p - 2), 3))
    snippet = [v.words[int(model.word_of_rank[r])] for r in snippet_ranks]
    assert snippet == ["quick", "brown", "fox"]


def test_space_report_accounts_everything():
    docs, v, idx, model, aux = build_engine()
    rep = wtbc.space_report(idx)
    assert rep["total"] == sum(v for k, v in rep.items() if k != "total")
    assert rep["level_bytes"] > 0
    rep2 = drb.space_report(aux)
    assert rep2["bitmap_bits_bytes"] > 0
