"""Scoring measures: tf-idf monotonicity over concatenation (the property
Algorithm 1's correctness rests on), BM25 shape/behavior."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import scoring


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=2, max_size=6),
       st.lists(st.integers(0, 50), min_size=2, max_size=6),
       st.lists(st.floats(0.0, 10.0, allow_nan=False), min_size=6, max_size=6))
def test_tfidf_monotone_over_concatenation(tf1, tf2, idf):
    """score(d1 ++ d2) >= max(score(d1), score(d2)) — paper §3.1."""
    q = min(len(tf1), len(tf2))
    t1 = jnp.asarray(tf1[:q], jnp.int32)
    t2 = jnp.asarray(tf2[:q], jnp.int32)
    w = jnp.asarray(idf[:q], jnp.float32)
    m = scoring.TfIdf()
    s1 = float(m.score(t1, w))
    s2 = float(m.score(t2, w))
    s12 = float(m.score(t1 + t2, w))
    assert s12 >= max(s1, s2) - 1e-4


def test_bm25_not_monotone_example():
    """Document-length normalization breaks concatenation monotonicity —
    the reason the paper restricts BM25 to the DRB strategy."""
    m = scoring.BM25()
    idf = jnp.asarray([2.0])
    # d1: tf=5, len 10; concat with an empty-ish long doc: tf same, len 1000
    s_short = float(m.score(jnp.asarray([5]), idf, jnp.float32(10.0),
                            jnp.float32(100.0)))
    s_concat = float(m.score(jnp.asarray([5]), idf, jnp.float32(1000.0),
                             jnp.float32(100.0)))
    assert s_concat < s_short


def test_idf_tables(small_index):
    idx, _ = small_index
    tf_idf = scoring.TfIdf().idf(idx)
    bm = scoring.BM25().idf(idx)
    assert tf_idf.shape == bm.shape == (idx.vocab_size,)
    df = np.asarray(idx.df)
    present = df > 0
    # rarer words score higher under both
    order = np.argsort(df[present])
    assert (np.diff(np.asarray(tf_idf)[present][order]) <= 1e-6).all()
