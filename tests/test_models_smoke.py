"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (the brief's smoke contract).
The FULL configs are exercised only via launch/dryrun.py (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base, registry
from repro.configs.lm_common import LM_SHAPES
from repro.data import pipeline
from repro.models import transformer as T
from repro.optim import adamw

RULES = base.make_rules(())          # no mesh on CPU tests


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


LM_ARCHS = ["qwen3-moe-235b-a22b", "llama4-scout-17b-a16e", "gemma2-9b",
            "qwen3-1.7b", "granite-3-8b"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_train_smoke(name):
    arch = registry.get(name)
    cfg = arch.config(smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    step = arch.make_step(cfg, "train", RULES)
    batch = pipeline.lm_batch(0, 0, batch=2, seq=16, vocab=cfg.vocab)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_decode_smoke(name):
    arch = registry.get(name)
    cfg = arch.config(smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    step = arch.make_step(cfg, "decode", RULES)
    caches = T.init_cache(cfg, 2, 32)
    logits, caches = step(params, caches, jnp.array([1, 2], jnp.int32),
                          jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_prefill_then_decode_consistency(name):
    """Greedy continuation from prefill caches matches full-forward logits."""
    arch = registry.get(name)
    cfg = arch.config(smoke=True)
    params = arch.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab)
    logits_full, _ = T.forward(params, toks, cfg, RULES)
    # decode token-by-token from an empty cache
    caches = T.init_cache(cfg, 1, 16)
    for t in range(8):
        logits_t, caches = T.decode_step(params, caches, toks[:, t],
                                         jnp.int32(t), cfg, RULES)
    np.testing.assert_allclose(np.asarray(logits_t[0]),
                               np.asarray(logits_full[0, -1]),
                               rtol=2e-2, atol=2e-3)


def test_egnn_smoke_all_shapes():
    arch = registry.get("egnn")
    for shape in ("full_graph_sm", "molecule"):
        cfg = arch.config_for(shape, smoke=True)
        params = arch.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_state(params)
        step = arch.make_step(cfg, "train", RULES)
        if cfg.graph_readout:
            batch = pipeline.molecule_batch(0, n_graphs=cfg.n_graphs,
                                            nodes_per=6, edges_per=10,
                                            d_feat=cfg.d_feat,
                                            n_classes=cfg.n_classes)
        else:
            batch = pipeline.random_graph(0, 64, 256, cfg.d_feat, cfg.n_classes)
        _, _, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_egnn_equivariance():
    """E(n) property: rotating+translating inputs leaves logits unchanged."""
    from repro.models import gnn
    arch = registry.get("egnn")
    cfg = arch.config_for("full_graph_sm", smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipeline.random_graph(3, 40, 160, cfg.d_feat, cfg.n_classes)
    logits = gnn.forward(params, batch, cfg, RULES)
    # random rotation (QR) + translation
    q, _ = np.linalg.qr(np.random.default_rng(0).standard_normal((3, 3)))
    batch2 = dict(batch)
    batch2["coords"] = batch["coords"] @ jnp.asarray(q.astype(np.float32)) + 5.0
    logits2 = gnn.forward(params, batch2, cfg, RULES)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=2e-4, atol=2e-4)


RECSYS = ["fm", "xdeepfm", "dlrm-mlperf", "sasrec"]


@pytest.mark.parametrize("name", RECSYS)
def test_recsys_train_and_serve_smoke(name):
    arch = registry.get(name)
    cfg = arch.config(smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    step = arch.make_step(cfg, "train", RULES)
    batch = pipeline.recsys_batch(0, 0, batch=16, cfg=cfg)
    _, _, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    serve = arch.make_step(cfg, "serve", RULES)
    out = serve(params, batch)
    if cfg.interaction == "self-attn-seq":
        assert out.shape == (16, cfg.embed_dim)
    else:
        assert out.shape == (16,)
        assert bool(((np.asarray(out) >= 0) & (np.asarray(out) <= 1)).all())


@pytest.mark.parametrize("name", RECSYS)
def test_recsys_retrieval_smoke(name):
    arch = registry.get(name)
    cfg = arch.config(smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0), cfg)
    step = arch.make_step(cfg, "retrieval", RULES)
    batch = pipeline.recsys_batch(0, 0, batch=1, cfg=cfg)
    batch = {k: v for k, v in batch.items() if k not in ("label", "pos", "neg")}
    n_cand = min(cfg.rows()[0] if cfg.interaction != "self-attn-seq"
                 else cfg.n_items, 256)
    batch["candidates"] = jnp.arange(n_cand, dtype=jnp.int32)
    scores, idxs = step(params, batch)
    assert scores.shape == (100,) and idxs.shape == (100,)
    s = np.asarray(scores)
    assert (np.diff(s) <= 1e-6).all()    # descending


def test_all_cells_enumerate():
    cells = list(registry.all_cells())
    assigned = [c for c in cells if c.arch != "wtbc"]
    assert len(assigned) == 40           # the brief's 40 cells
    skips = [c for c in assigned if c.skip]
    assert {(c.arch, c.shape) for c in skips} == {
        ("qwen3-moe-235b-a22b", "long_500k"),
        ("qwen3-1.7b", "long_500k"),
        ("granite-3-8b", "long_500k")}
