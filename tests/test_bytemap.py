"""bytemap rank/select vs numpy oracles (property-based)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import bytemap


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5000),
       st.sampled_from([256, 512, 2048]))
def test_rank_matches_oracle(seed, n, block):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, n).astype(np.uint8)
    bm = bytemap.build(data, block=block)
    for _ in range(10):
        b = int(rng.integers(0, 256))
        p = int(rng.integers(0, n + 1))
        assert int(bytemap.rank(bm, jnp.uint8(b), jnp.int32(p))) == \
            bytemap.rank_np(data, b, p)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4000),
       st.sampled_from([256, 1024]))
def test_select_matches_oracle(seed, n, block):
    rng = np.random.default_rng(seed)
    # low-entropy alphabet => many repeats per byte value
    data = rng.integers(0, 7, n).astype(np.uint8)
    bm = bytemap.build(data, block=block)
    for _ in range(10):
        b = int(rng.integers(0, 8))
        occ = int((data == b).sum())
        j = int(rng.integers(1, occ + 2)) if occ else 1
        assert int(bytemap.select(bm, jnp.uint8(b), jnp.int32(j))) == \
            bytemap.select_np(data, b, j)


def test_rank_select_inverse():
    rng = np.random.default_rng(4)
    data = rng.integers(0, 3, 3000).astype(np.uint8)
    bm = bytemap.build(data, block=256)
    for b in range(3):
        occ = int((data == b).sum())
        for j in [1, occ // 2, occ]:
            if j < 1:
                continue
            p = int(bytemap.select(bm, jnp.uint8(b), jnp.int32(j)))
            assert int(bytemap.rank(bm, jnp.uint8(b), jnp.int32(p + 1))) == j
            assert data[p] == b


def test_count_range_edges():
    data = np.array([5, 5, 1, 5], np.uint8)
    bm = bytemap.build(data, block=256)
    assert int(bytemap.count_range(bm, jnp.uint8(5), jnp.int32(0), jnp.int32(4))) == 3
    assert int(bytemap.count_range(bm, jnp.uint8(5), jnp.int32(1), jnp.int32(1))) == 0
    assert int(bytemap.count_range(bm, jnp.uint8(9), jnp.int32(0), jnp.int32(4))) == 0
