"""Checkpoint: roundtrip, crash-safety, CRC, restart continuity."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime import fault_tolerance as ft


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(7), "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 5, tree)
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_npy_roundtrip_with_mmap_and_meta(tmp_path):
    """fmt='npy' checkpoints restore leaf-exact, memory-mapped, and carry
    committed user metadata (the serving-snapshot load path)."""
    tree = make_tree(4)
    ckpt.save(tmp_path, 7, tree, fmt="npy", meta={"backend": "single", "v": 1})
    manifest, step = ckpt.read_manifest(tmp_path)
    assert step == 7
    assert manifest["format"] == "npy"
    assert manifest["user_meta"] == {"backend": "single", "v": 1}
    restored, _ = ckpt.restore(tmp_path, tree, mmap=True, verify_crc=False)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the big leaves really are memory-mapped, not materialized
    flat = jax.tree.leaves(restored)
    assert any(isinstance(l, np.memmap) for l in flat)
    # CRC verification still works on the npy layout
    restored2, _ = ckpt.restore(tmp_path, tree, verify_crc=True)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mmap_requires_npy(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 1, tree)                     # default npz
    with pytest.raises(ValueError, match="npy"):
        ckpt.restore(tmp_path, tree, mmap=True)
    with pytest.raises(ValueError, match="format"):
        ckpt.save(tmp_path, 2, tree, fmt="pickle")


def test_restore_picks_latest_committed(tmp_path):
    ckpt.save(tmp_path, 1, make_tree(1))
    ckpt.save(tmp_path, 9, make_tree(9))
    # a torn write: tmp dir without manifest must be ignored
    (tmp_path / "step_00000099.tmp").mkdir()
    restored, step = ckpt.restore(tmp_path, make_tree())
    assert step == 9


def test_crc_detects_corruption(tmp_path):
    tree = make_tree()
    d = ckpt.save(tmp_path, 3, tree)
    man = json.loads((d / "MANIFEST.json").read_text())
    man["leaves"][0]["crc32"] ^= 0xDEAD
    (d / "MANIFEST.json").write_text(json.dumps(man))
    with pytest.raises(IOError):
        ckpt.restore(tmp_path, tree)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        saver.save_async(s, make_tree(s))
    saver.wait()
    assert ckpt.list_steps(tmp_path) == [20, 30]   # GC keeps the last 2


def test_restart_continuity(tmp_path):
    """Loss trajectory with injected failures == uninterrupted trajectory."""
    def step_fn(params, opt, batch):
        g = batch["x"]
        params = jax.tree.map(lambda p: p - 0.1 * g, params)
        opt = opt + 1
        return params, opt, {"loss": jnp.sum(params["w"] ** 2)}

    def batch_fn(step):
        return {"x": jnp.float32(step % 3 - 1)}

    init = {"params": {"w": jnp.ones(4)}, "opt": jnp.int32(0)}

    log_clean = []
    ft.run_with_restarts(init, 30, step_fn, batch_fn, tmp_path / "clean",
                         ckpt_every=7, metrics_log=log_clean)
    log_faulty = []
    ft.run_with_restarts(init, 30, step_fn, batch_fn, tmp_path / "faulty",
                         ckpt_every=7, failures=(11, 23),
                         metrics_log=log_faulty)
    clean = {s: m["loss"] for s, m in log_clean}
    faulty = {s: m["loss"] for s, m in log_faulty}
    # every step present, and the last occurrence of each step's loss matches
    assert set(clean) == set(faulty)
    for s in clean:
        assert abs(clean[s] - faulty[s]) < 1e-6, s


def test_straggler_watchdog():
    wd = ft.StragglerWatchdog(alpha=0.5, threshold=2.0)
    for _ in range(5):
        wd.observe(0, 0.1)
    assert wd.observe(5, 1.0)            # 10x the EWMA -> flagged
    assert wd.flagged


def test_elastic_restore_resharding(tmp_path):
    """Restore places leaves with new shardings (device_put path)."""
    tree = make_tree()
    ckpt.save(tmp_path, 2, tree)
    dev = jax.devices()[0]
    sharding = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    restored, _ = ckpt.restore(tmp_path, tree, shardings=sharding)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)
