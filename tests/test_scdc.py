"""(s,c)-Dense Code: roundtrip, structure, optimality (property-based)."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import scdc


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 255), st.integers(1, 3000))
def test_code_lengths_band_structure(s, v):
    if scdc.capacity(s) < v:
        return
    lens = scdc.code_lengths(s, v)
    c = 256 - s
    assert (np.diff(lens) >= 0).all()                  # non-decreasing
    assert (lens[:min(s, v)] == 1).all()               # first s are 1 byte
    if v > s:
        assert (lens[s:min(s + s * c, v)] == 2).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 4000), st.integers(50, 5000))
def test_roundtrip(seed, vocab, n_tokens):
    rng = np.random.default_rng(seed)
    freqs = rng.zipf(1.4, vocab).astype(np.int64)
    model = scdc.fit(freqs, reserve_first=0)
    toks = rng.integers(0, vocab, n_tokens)
    enc = model.encode_tokens(toks)
    dec = model.decode_bytes(enc)
    assert np.array_equal(dec, toks)
    # stream length matches the analytic size
    ranks = model.rank_of_word[toks]
    assert len(enc) == int(model.lens[ranks].astype(np.int64).sum())


def test_encode_decode_rank_inverse():
    s = 200
    for r in [0, 1, 199, 200, 5000, 100_000, 500_000]:
        codes, lens = scdc.encode_table(s, r + 1)
        byteseq = list(codes[r][: lens[r]])
        assert scdc.decode_rank(s, byteseq) == r


def test_reserved_separator_is_single_stopper():
    rng = np.random.default_rng(0)
    freqs = rng.integers(1, 100, 1000)
    freqs[0] = 1                      # rare, but must still get rank 0
    model = scdc.fit(freqs, reserve_first=0)
    assert model.rank_of_word[0] == 0
    assert model.lens[0] == 1 and model.codes[0, 0] == 0


def test_optimal_sc_beats_neighbors():
    rng = np.random.default_rng(1)
    freqs_desc = np.sort(rng.zipf(1.3, 5000))[::-1].astype(np.int64)
    s, c = scdc.optimal_sc(freqs_desc)
    best = scdc.compressed_size(s, freqs_desc)
    for s2 in (s - 1, s + 1):
        if 1 <= s2 <= 255 and scdc.capacity(s2) >= len(freqs_desc):
            assert scdc.compressed_size(s2, freqs_desc) >= best
