"""Document-sharded retrieval == single-host results (8 simulated devices).

Runs in a subprocess because XLA's host device count is locked at first jax
init (the main pytest process must keep seeing 1 CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import wtbc, ranked, drb, scoring, distributed
    from repro.text import corpus

    cp = corpus.make_corpus(n_docs=96, mean_doc_len=40, vocab_size=300, seed=5)
    sharded, model = distributed.build_sharded(cp.doc_tokens, cp.vocab_size,
                                               n_shards=8, block=512)
    idx, _ = wtbc.build_index(cp.doc_tokens, cp.vocab_size, block=512)
    measure = scoring.TfIdf()
    idf = measure.idf(idx)
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shards",))
    rng = np.random.default_rng(11)
    df = np.asarray(idx.df)
    pool = np.flatnonzero((df >= 2) & (df <= 50))
    fails = 0
    for trial in range(2):
        ws = rng.choice(pool, size=3, replace=False)
        words = jnp.asarray(ws, jnp.int32); wmask = jnp.ones(3, bool)
        for method, conj in [("dr-and", True), ("dr-or", False),
                             ("drb-and", True), ("drb-or", False)]:
            bf = ranked.topk_bruteforce(idx, words, wmask, idf, k=10,
                                        conjunctive=conj)
            res = distributed.distributed_topk(sharded, words, wmask, k=10,
                method=method, mesh=mesh, shard_axes="shards", max_df_cap=64)
            bs = np.sort(np.asarray(bf.scores))[::-1]
            ds = np.sort(np.asarray(res.scores))[::-1]
            if not (int(bf.n_found) == int(res.n_found)
                    and np.allclose(bs, ds, atol=1e-4)):
                fails += 1
                print("MISMATCH", method, trial)
    # batched queries through the same path
    wsb = jnp.asarray(np.stack([rng.choice(pool, 3, replace=False)
                                for _ in range(4)]), jnp.int32)
    res = distributed.distributed_topk(sharded, wsb, jnp.ones((4,3), bool),
        k=5, method="dr-or", mesh=mesh, shard_axes="shards")
    assert res.docs.shape == (4, 5), res.docs.shape
    for b in range(4):
        bf = ranked.topk_bruteforce(idx, wsb[b], jnp.ones(3, bool), idf,
                                    k=5, conjunctive=False)
        if not np.allclose(np.sort(np.asarray(bf.scores)),
                           np.sort(np.asarray(res.scores[b])), atol=1e-4):
            fails += 1; print("BATCH MISMATCH", b)
    print("FAILS", fails)
    raise SystemExit(1 if fails else 0)
""")


@pytest.mark.slow
def test_sharded_equals_single_host():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=
                       os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
