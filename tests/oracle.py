"""Brute-force NumPy retrieval oracle — the differential-test ground truth.

Everything here rescans the *raw* token lists (never the WTBC, never JAX), so
any agreement with the engine is evidence about the compressed index and the
jitted query kernels, not a shared bug.  The oracle mirrors the engine's
*semantics* exactly — per-slot tf (duplicate query words count twice), the
DRB stopword rule (words with idf < eps carry no bitmap and drop out of DRB
conjunctions and scoring), DR's score>0 disjunctive eligibility, phrase
adjacency, minimal proximity cover windows and their leftmost tie-breaks —
while computing everything the dumb O(N · doc_len) way.

``search_oracle`` is the one entry point: it returns the *full* eligible
ranking as ``{doc: {"score", "pos", "len"}}``; differential tests query the
engine with ``k = n_docs`` and compare per-document, which sidesteps
tie-order entirely.
"""
from __future__ import annotations

import numpy as np

INT32_MAX = 2**31 - 1


# ---------------------------------------------------------------------------
# collection statistics
# ---------------------------------------------------------------------------

def doc_freqs(doc_tokens, vocab_size: int) -> np.ndarray:
    df = np.zeros(vocab_size, dtype=np.int64)
    for d in doc_tokens:
        df[np.unique(np.asarray(d))] += 1
    return df


def idf_table(doc_tokens, vocab_size: int, measure: str) -> np.ndarray:
    """Per-word idf, mirroring scoring.TfIdf / scoring.BM25."""
    df = doc_freqs(doc_tokens, vocab_size).astype(np.float64)
    n = float(len(doc_tokens))
    if measure == "tfidf":
        return np.log(n / np.maximum(df, 1.0))
    if measure == "bm25":
        return np.log(1.0 + (n - df + 0.5) / (df + 0.5))
    raise ValueError(measure)


def has_bitmap(doc_tokens, vocab_size: int, eps: float = 1e-6) -> np.ndarray:
    """Which words get a DRB tf bitmap (mirrors drb.build_aux)."""
    df = doc_freqs(doc_tokens, vocab_size).astype(np.float64)
    n = max(len(doc_tokens), 1)
    idf = np.log(n / np.maximum(df, 1.0))
    return (idf >= eps) & (df > 0)


def tf_matrix(doc_tokens, word_ids) -> np.ndarray:
    """(N, Q) per-slot term frequencies (duplicate slots repeat)."""
    word_ids = np.asarray(word_ids)
    out = np.zeros((len(doc_tokens), len(word_ids)), dtype=np.int64)
    for d, doc in enumerate(doc_tokens):
        doc = np.asarray(doc)
        for q, w in enumerate(word_ids):
            out[d, q] = int(np.sum(doc == w))
    return out


def score_docs(tf: np.ndarray, idf_w: np.ndarray, doc_len: np.ndarray,
               measure: str, avg_dl: float | None = None,
               k1: float = 1.2, b: float = 0.75) -> np.ndarray:
    """(N,) scores from per-slot tf — mirrors scoring.TfIdf/BM25.score.
    ``avg_dl`` defaults to the mean of ``doc_len`` (pass the collection
    average when scoring a slice)."""
    tf = tf.astype(np.float64)
    if measure == "tfidf":
        return tf @ idf_w
    if measure == "bm25":
        if avg_dl is None:
            avg_dl = float(doc_len.sum()) / len(doc_len)
        norm = 1.0 - b + b * (doc_len.astype(np.float64) / avg_dl)
        part = tf * (k1 + 1.0) / (tf + k1 * norm[:, None])
        return part @ idf_w
    raise ValueError(measure)


# ---------------------------------------------------------------------------
# positional primitives
# ---------------------------------------------------------------------------

def phrase_occurrences(doc, phrase) -> list[int]:
    """Start offsets of every exact consecutive in-order match."""
    doc = list(np.asarray(doc))
    phrase = list(np.asarray(phrase))
    if not phrase or len(phrase) > len(doc):
        return []
    return [i for i in range(len(doc) - len(phrase) + 1)
            if doc[i:i + len(phrase)] == phrase]


def min_cover_window(doc, word_ids) -> tuple[int, int]:
    """(width, start) of the smallest window of ``doc`` containing one
    occurrence of every word in ``word_ids`` (a multiset — duplicates are
    satisfied by one occurrence); (INT32_MAX, -1) when none exists.  Ties on
    width resolve to the smallest start."""
    doc = np.asarray(doc)
    occ = {int(w): np.flatnonzero(doc == w) for w in set(int(w) for w in word_ids)}
    if any(len(v) == 0 for v in occ.values()):
        return INT32_MAX, -1
    best = (INT32_MAX, -1)
    for p in range(len(doc)):
        lasts = []
        for pos in occ.values():
            prior = pos[pos <= p]
            if len(prior) == 0:
                lasts = None
                break
            lasts.append(int(prior[-1]))
        if lasts is None:
            continue
        start = min(lasts)
        width = p - start + 1
        if width < best[0]:
            best = (width, start)
    return best


# ---------------------------------------------------------------------------
# the full ranking oracle
# ---------------------------------------------------------------------------

def search_oracle(doc_tokens, query, *, mode: str, measure: str = "tfidf",
                  strategy: str = "dr", window: int | None = None,
                  vocab_size: int | None = None,
                  eps: float = 1e-6) -> dict[int, dict]:
    """Full eligible ranking for one query: ``{doc: {"score", "pos", "len"}}``.

    mode:     "and" | "or" | "phrase" | "near".
    strategy: "dr" | "drb" — matters for and/or only (DRB excludes bitmap-less
              stopwords from conjunction and scoring; DR does not).
    ``pos``/``len`` are -1 for the non-positional modes.
    """
    query = [int(w) for w in query]
    if vocab_size is None:
        vocab_size = max((int(np.max(d)) for d in doc_tokens if len(d)),
                         default=0) + 1
        vocab_size = max(vocab_size, max(query, default=0) + 1)
    doc_len = np.array([len(d) for d in doc_tokens], dtype=np.int64)
    idf = idf_table(doc_tokens, vocab_size, measure)
    tf = tf_matrix(doc_tokens, query)                      # (N, Q)

    if mode in ("phrase", "near"):
        valid = np.ones(len(query), dtype=bool)
    elif strategy == "drb":
        valid = has_bitmap(doc_tokens, vocab_size, eps)[query]
    elif strategy == "dr":
        valid = np.ones(len(query), dtype=bool)
    else:
        raise ValueError(strategy)

    idf_w = np.where(valid, idf[query], 0.0)
    avg_dl = float(doc_len.sum()) / len(doc_len)
    scores = score_docs(tf, idf_w, doc_len, measure, avg_dl)

    out: dict[int, dict] = {}
    df_q = doc_freqs(doc_tokens, vocab_size)[query]
    for d in range(len(doc_tokens)):
        pos = length = -1
        if mode == "and":
            if strategy == "drb":
                # absent (df=0) masked word empties the conjunction; bitmap-
                # less stopwords drop out of it (drb.topk_drb_and contract)
                eligible = (not np.any(df_q == 0) and np.any(valid)
                            and bool(np.all(tf[d][valid] > 0)))
            else:
                eligible = bool(np.all(tf[d] > 0))
        elif mode == "or":
            if strategy == "drb":
                eligible = bool(np.any(tf[d][valid] > 0))
            else:
                eligible = scores[d] > 0.0                 # ranked.seg_valid
        elif mode == "phrase":
            occ = phrase_occurrences(doc_tokens[d], query)
            eligible = len(occ) > 0
            if eligible:
                pos, length = occ[0], len(query)
                scores[d] = score_docs(
                    np.full((1, len(query)), len(occ), dtype=np.int64),
                    idf_w, doc_len[d:d + 1], measure, avg_dl)[0]
        elif mode == "near":
            width, start = min_cover_window(doc_tokens[d], query)
            eligible = width <= int(window)
            if eligible:
                pos, length = start, width
        else:
            raise ValueError(mode)
        if eligible:
            out[d] = {"score": float(scores[d]), "pos": pos, "len": length}
    return out
