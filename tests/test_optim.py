"""Optimizer substrate: AdamW math, schedule, EF-int8 gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=1, total_steps=200,
                            weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = adamw.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, m = adamw.apply_updates(params, state, grads, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6              # end of warmup
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decay
    assert lrs[-1] >= 0.1 - 1e-6                 # floor


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_ef_int8_compression_unbiased_over_time():
    """Error feedback: quantization error is carried, so the SUM of
    dequantized gradients converges to the sum of true gradients."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(32).astype(np.float32))
              for _ in range(50)]
    ef = adamw.ef_init({"w": g_true[0]})
    acc_deq = jnp.zeros(32)
    acc_true = jnp.zeros(32)
    for g in g_true:
        deq, ef = adamw.ef_compress_tree({"w": g}, ef)
        acc_deq = acc_deq + deq["w"]
        acc_true = acc_true + g
    # |sum error| = |final residual| <= one quantization step of the largest
    # carried value (|x| <= |g| + |prev residual|)
    err = float(jnp.max(jnp.abs(acc_deq - acc_true)))
    gmax = max(float(jnp.max(jnp.abs(g))) for g in g_true)
    assert err <= 3 * gmax / 127.0 + 1e-5, (err, gmax)


def test_ef_payload_is_int8_sized():
    g = {"w": jnp.ones((1000,), jnp.float32)}
    deq, ef = adamw.ef_compress_tree(g, adamw.ef_init(g))
    # the quantized wire format is int8: 4x smaller than f32
    assert np.asarray(deq["w"]).dtype == np.float32      # dequantized locally
    np.testing.assert_allclose(np.asarray(deq["w"]), 1.0, rtol=0.02)
