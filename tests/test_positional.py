"""repro.core.positional — unit tests on hand-checked corpora.

The differential suite (test_oracle_diff.py) pins these kernels against the
NumPy oracle on randomized corpora; here the expected numbers are written out
by hand so a failure localizes immediately.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import positional, scoring, wtbc
from repro.engine import EngineConfig, SearchEngine

#              0  1  2  3  4  5  6
DOCS = [
    np.array([1, 2, 3, 9, 1, 2, 3], dtype=np.int64),   # "1 2 3" at 0 and 4
    np.array([3, 2, 1, 9, 9, 9], dtype=np.int64),      # reversed — no phrase
    np.array([1, 9, 2, 9, 9, 3], dtype=np.int64),      # spread: window [0,5]
    np.array([4, 4, 4, 4], dtype=np.int64),            # none of the words
    np.array([1, 2, 9, 1, 2, 3], dtype=np.int64),      # "1 2 3" at 3
]
VOCAB = 12


@pytest.fixture(scope="module")
def built():
    idx, model = wtbc.build_index(DOCS, VOCAB, block=128)
    return idx, model


def _words(model, ids):
    return jnp.asarray(model.rank_of_word[np.asarray(ids)], jnp.int32)


def test_phrase_tables_hand_checked(built):
    idx, model = built
    tf, first, iters = positional.phrase_tables(
        idx, _words(model, [1, 2, 3]), jnp.ones(3, bool))
    np.testing.assert_array_equal(np.asarray(tf), [2, 0, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(first), [0, -1, -1, -1, 3])
    assert int(iters) > 0


def test_near_tables_hand_checked(built):
    idx, model = built
    tf, win, pos, _ = positional.near_tables(
        idx, _words(model, [1, 3]), jnp.ones(2, bool))
    # doc0: "1 . 3" at [2,4] -> width 3 wait: positions of 1: {0,4}, 3: {2,6}
    #   best pair (4,6) width 3; (0,2) width 3 -> leftmost start 0
    np.testing.assert_array_equal(np.asarray(win)[:3], [3, 3, 6])
    np.testing.assert_array_equal(np.asarray(pos)[:3], [0, 0, 0])
    assert int(np.asarray(win)[3]) == positional.INT32_MAX  # word absent
    # doc4: 1 at {0,3}, 3 at {5} -> window [3,5] width 3
    assert int(np.asarray(win)[4]) == 3 and int(np.asarray(pos)[4]) == 3
    # tf rows are per-slot term frequencies
    np.testing.assert_array_equal(np.asarray(tf)[0], [2, 1, 1, 0, 2])
    np.testing.assert_array_equal(np.asarray(tf)[1], [2, 1, 1, 0, 1])


def test_single_word_phrase_equals_occurrences(built):
    idx, model = built
    tf, first, _ = positional.phrase_tables(
        idx, _words(model, [9]), jnp.ones(1, bool))
    np.testing.assert_array_equal(np.asarray(tf), [1, 3, 3, 0, 1])
    np.testing.assert_array_equal(np.asarray(first), [3, 3, 1, -1, 2])


def test_doc_positions_extraction(built):
    idx, model = built
    w9 = _words(model, [9])[0]
    pos = positional.doc_positions(idx, w9, jnp.int32(2), cap=4)
    np.testing.assert_array_equal(np.asarray(pos), [1, 3, 4, -1])
    pos = positional.doc_positions(idx, w9, jnp.int32(3), cap=4)
    np.testing.assert_array_equal(np.asarray(pos), [-1, -1, -1, -1])


def test_topk_positional_masked_slots(built):
    """Padding slots (mask False) must not affect the phrase."""
    idx, model = built
    m = scoring.TfIdf()
    words = jnp.concatenate([_words(model, [1, 2, 3]), jnp.zeros(2, jnp.int32)])
    mask = jnp.array([True, True, True, False, False])
    res = positional.topk_positional(idx, words, mask, m.idf(idx), k=5,
                                     phrase=True, measure=m)
    n = int(res.n_found)
    assert {int(d) for d in np.asarray(res.docs)[:n]} == {0, 4}
    assert all(int(l) == 3 for l in np.asarray(res.match_len)[:n])


def test_engine_phrase_beats_unordered(built):
    """Facade end-to-end: phrase vs AND on the same words differ exactly on
    ordering; near honours the window; matches() payloads line up."""
    engine = SearchEngine.build(DOCS, EngineConfig(block=128),
                                vocab_size=VOCAB)
    res_and = engine.search([[1, 2, 3]], k=5, mode="and")
    res_phr = engine.search([[1, 2, 3]], k=5, mode="phrase")
    assert {d for d, _ in res_and.hits(0)} == {0, 1, 2, 4}
    assert {d for d, *_ in res_phr.matches(0)} == {0, 4}
    assert res_phr.matches(0)[0][0] == 0          # two matches outrank one
    # near is unordered: doc1's "3 2 1" also fits a width-3 window
    res_near = engine.search([[1, 2, 3]], k=5, mode="near", window=3)
    assert {d for d, *_ in res_near.matches(0)} == {0, 1, 4}
    res_wide = engine.search([[1, 2, 3]], k=5, mode="near", window=6)
    assert {d for d, *_ in res_wide.matches(0)} == {0, 1, 2, 4}
    # doc1 "3 2 1": minimal window is the whole prefix, width 3
    d1 = dict((d, (p, l)) for d, _, p, l in res_wide.matches(0))[1]
    assert d1 == (0, 3)


def test_engine_word_positions():
    engine = SearchEngine.build(DOCS, EngineConfig(block=128),
                                vocab_size=VOCAB)
    pos = engine.word_positions(0, [1, 9, 11], cap=4)
    np.testing.assert_array_equal(pos[1], [0, 4])
    np.testing.assert_array_equal(pos[9], [3])
    np.testing.assert_array_equal(pos[11], [])
    with pytest.raises(ValueError, match="word id"):
        engine.word_positions(0, [0])


def test_empty_and_absent_queries(built):
    idx, model = built
    m = scoring.TfIdf()
    # word 11 never occurs: phrase and near must both come back empty
    words = _words(model, [1, 11])
    res = positional.topk_positional(idx, words, jnp.ones(2, bool), m.idf(idx),
                                     k=5, phrase=True, measure=m)
    assert int(res.n_found) == 0
    res = positional.topk_positional(idx, words, jnp.ones(2, bool), m.idf(idx),
                                     k=5, phrase=False, measure=m, window=50)
    assert int(res.n_found) == 0
    # fully-masked query: empty, not an error, at the kernel level
    res = positional.topk_positional(idx, words, jnp.zeros(2, bool),
                                     m.idf(idx), k=5, phrase=True, measure=m)
    assert int(res.n_found) == 0
