"""WTBC decode/count/locate vs direct token-array oracles."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import wtbc
from repro.text import corpus


def flat_ranks(cp, model):
    flat = np.concatenate([np.concatenate([d, [0]]) for d in cp.doc_tokens])
    return model.rank_of_word[flat]


def test_decode_matches(small_index, small_corpus):
    idx, model = small_index
    ranks = flat_ranks(small_corpus, model)
    rng = np.random.default_rng(0)
    for p in rng.integers(0, len(ranks), 25):
        assert int(wtbc.decode_at(idx, jnp.int32(p))) == ranks[p]


def test_count_range_matches(small_index, small_corpus):
    idx, model = small_index
    ranks = flat_ranks(small_corpus, model)
    rng = np.random.default_rng(1)
    for _ in range(25):
        w = int(ranks[rng.integers(0, len(ranks))])
        lo = int(rng.integers(0, len(ranks)))
        hi = int(rng.integers(lo, len(ranks) + 1))
        got = int(wtbc.count_range(idx, jnp.int32(w), jnp.int32(lo), jnp.int32(hi)))
        assert got == int((ranks[lo:hi] == w).sum())


def test_locate_matches(small_index, small_corpus):
    idx, model = small_index
    ranks = flat_ranks(small_corpus, model)
    rng = np.random.default_rng(2)
    for _ in range(25):
        w = int(ranks[rng.integers(0, len(ranks))])
        occ = np.flatnonzero(ranks == w)
        j = int(rng.integers(1, len(occ) + 1))
        assert int(wtbc.locate(idx, jnp.int32(w), jnp.int32(j))) == occ[j - 1]


def test_full_decode_roundtrip(small_index, small_corpus):
    idx, model = small_index
    assert np.array_equal(wtbc.decode_all_np(idx, model),
                          flat_ranks(small_corpus, model))


def test_doc_geometry(small_index, small_corpus):
    idx, model = small_index
    lens = [len(d) for d in small_corpus.doc_tokens]
    starts = np.cumsum([0] + [l + 1 for l in lens[:-1]])
    for d in [0, 1, len(lens) // 2, len(lens) - 1]:
        lo, hi = wtbc.segment_extent(idx, jnp.int32(d), jnp.int32(d + 1))
        assert int(lo) == starts[d]
        # extent ends at the separator (hi = next doc start incl. the '$')
        mid = starts[d] + lens[d] // 2
        assert int(wtbc.doc_of_pos(idx, jnp.int32(mid))) == d


def test_extract_snippet(small_index, small_corpus):
    idx, model = small_index
    ranks = flat_ranks(small_corpus, model)
    got = np.asarray(wtbc.extract(idx, jnp.int32(37), 12))
    assert np.array_equal(got, ranks[37:49])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(5, 40), st.integers(50, 400))
def test_build_properties_random_corpora(seed, n_docs, vocab):
    """Property sweep: whole-collection decode is the identity; df/occ agree
    with direct counting (drives corpus shape, skew, vocab)."""
    cp = corpus.make_corpus(n_docs=n_docs, mean_doc_len=20, vocab_size=vocab,
                            seed=seed % 10_000)
    idx, model = wtbc.build_index(cp.doc_tokens, cp.vocab_size, block=256)
    flat = np.concatenate([np.concatenate([d, [0]]) for d in cp.doc_tokens])
    ranks = model.rank_of_word[flat]
    assert np.array_equal(wtbc.decode_all_np(idx, model), ranks)
    occ = np.bincount(ranks, minlength=model.vocab_size)
    assert np.array_equal(np.asarray(idx.occ), occ.astype(np.int32))
    df = cp.doc_freqs()
    df_ranked = df[np.asarray(model.word_of_rank)]
    assert np.array_equal(np.asarray(idx.df), df_ranked.astype(np.int32))
