"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to launch/dryrun.py).

Corpus / index / engine fixtures are all session-scoped: WTBC builds and the
first jit compile dominate test wall-clock, so every module shares one build
instead of paying it per module."""
import numpy as np
import pytest

from repro.core import drb, scoring, wtbc
from repro.engine import EngineConfig, SearchEngine
from repro.text import corpus


@pytest.fixture(scope="session")
def small_corpus():
    return corpus.make_corpus(n_docs=120, mean_doc_len=60, vocab_size=500, seed=3)


@pytest.fixture(scope="session")
def engine_corpus():
    return corpus.make_corpus(n_docs=90, mean_doc_len=50, vocab_size=400, seed=9)


@pytest.fixture(scope="session")
def engine(engine_corpus):
    return SearchEngine.build(engine_corpus, EngineConfig(block=512))


@pytest.fixture(scope="session")
def query_batch(engine_corpus):
    df = engine_corpus.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 40))
    rng = np.random.default_rng(4)
    return np.stack([rng.choice(pool, 3, replace=False) for _ in range(3)])


@pytest.fixture(scope="session")
def small_index(small_corpus):
    idx, model = wtbc.build_index(small_corpus.doc_tokens,
                                  small_corpus.vocab_size, block=512)
    return idx, model


@pytest.fixture(scope="session")
def small_aux(small_index, small_corpus):
    idx, model = small_index
    return drb.build_aux(idx, model, small_corpus.doc_tokens, eps=1e-6)


@pytest.fixture(scope="session")
def tfidf():
    return scoring.TfIdf()
