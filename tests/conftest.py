"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to launch/dryrun.py)."""
import numpy as np
import pytest

from repro.core import drb, scoring, wtbc
from repro.text import corpus


@pytest.fixture(scope="session")
def small_corpus():
    return corpus.make_corpus(n_docs=120, mean_doc_len=60, vocab_size=500, seed=3)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    idx, model = wtbc.build_index(small_corpus.doc_tokens,
                                  small_corpus.vocab_size, block=512)
    return idx, model


@pytest.fixture(scope="session")
def small_aux(small_index, small_corpus):
    idx, model = small_index
    return drb.build_aux(idx, model, small_corpus.doc_tokens, eps=1e-6)


@pytest.fixture(scope="session")
def tfidf():
    return scoring.TfIdf()
