"""Megabatch core + admission lanes + hot-swap serving — invariance suites.

The re-landed continuous-batching core (DESIGN.md §8) rests on one
structural claim: the search frontier is ordered by a TOTAL lexicographic
key ``(score desc, d0 asc, d1 desc)``, so pop/emission order cannot depend
on insertion order, beam width, pool capacity, or batching schedule.  This
module pins that claim at every layer:

* **order layer** — property tests: heap pop sequences are identical across
  P ∈ {1, 4, 16} and across insertion orders (ties included), and the dense
  pool's ``lex_argmax`` extraction reproduces the heap sequence at any
  capacity / slot placement;
* **kernel layer** — a ≥200-case seeded differential sweep pinning
  ``mega=True`` batches BITWISE against per-row serial execution at matched
  Q buckets (AND/OR × tfidf/bm25 × DR/DRB), plus the documented
  cross-Q-bucket BM25 ulp-drift caveat;
* **admission layer** — factor-8 work buckets, the heavy batch-1 lane, the
  oldest-request starvation bound, EWMA-adaptive coalescing wait;
* **serving layer** — mega-batched / cached / swapped-engine / snapshot-
  restored answers all bitwise equal to direct ``engine.search``, the
  drain -> swap -> clear protocol, and zero-copy snapshot boot.
"""
import queue
import threading
import time
import types

import jax
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import heap as H
from repro.engine import EngineConfig, SearchEngine
from repro.serve import QueryProfile, SearchServer, ShedError, snapshot
from repro.serve.batcher import DEFAULT_LANE, Lane, MicroBatcher, work_bucket
from repro.text import corpus

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# shared fixtures / helpers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mega_queries(engine_corpus):
    df = engine_corpus.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 40))
    rng = np.random.default_rng(17)
    return [list(map(int, rng.choice(pool, 3, replace=False)))
            for _ in range(10)]


@pytest.fixture(scope="module")
def engine_b(small_corpus):
    """A second engine over a different corpus — swap-target with a distinct
    content tag (word ids < 400 are valid in both vocabularies)."""
    return SearchEngine.build(small_corpus, EngineConfig(block=512))


def _row_equals(row, res, b=0):
    np.testing.assert_array_equal(row.docs, np.asarray(res.docs[b]))
    np.testing.assert_array_equal(row.scores, np.asarray(res.scores[b]))
    assert row.n_found == int(res.n_found[b])


def _lex_sorted(entries):
    """The total priority order: score desc, d0 asc, d1 desc."""
    return sorted(entries, key=lambda e: (-e[0], e[1], -e[2]))


def _heap_pop_all(entries, p=1):
    """Push ``(score, d0, d1)`` entries, then drain via pop (p=1) or pop_p."""
    h = H.make(len(entries) + 4, 2)
    for s, d0, d1 in entries:
        h = H.push(h, jnp.float32(s), jnp.array([d0, d1], jnp.int32))
    out = []
    while int(h.size) > 0:
        if p == 1:
            s, pay, h = H.pop(h)
            out.append((float(s), int(pay[0]), int(pay[1])))
        else:
            ss, pp, vv, h = H.pop_p(h, p)
            out.extend((float(s), int(pl[0]), int(pl[1]))
                       for s, pl, v in zip(np.asarray(ss), np.asarray(pp),
                                           np.asarray(vv)) if v)
    return out


SEGMENTS = st.lists(
    st.tuples(st.sampled_from([0.0, 1.5, 3.0]),     # few scores => many ties
              st.integers(0, 7), st.integers(8, 15)),
    min_size=1, max_size=14, unique=True)


# ---------------------------------------------------------------------------
# order layer: schedule invariance of the total lex order
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(entries=SEGMENTS, seed=st.integers(0, 2**31 - 1))
def test_pop_sequence_invariant_across_widths_and_orders(entries, seed):
    """The flattened pop sequence is THE sorted total order — identical for
    pop, pop_p(4), pop_p(16), and for any insertion order (distinct keys,
    heavy score ties)."""
    expect = _lex_sorted(entries)
    shuffled = list(entries)
    np.random.default_rng(seed).shuffle(shuffled)
    for order in (entries, shuffled):
        for p in (1, 4, 16):
            assert _heap_pop_all(order, p) == expect, (order, p)


@settings(max_examples=10, deadline=None)
@given(entries=SEGMENTS, seed=st.integers(0, 2**31 - 1))
def test_pool_extraction_matches_heap_at_any_capacity(entries, seed):
    """Dense-pool extract-max (``lex_argmax`` + slot clear) reproduces the
    heap's pop sequence whatever the pool capacity or slot placement —
    slot position carries no ordering information."""
    expect = _lex_sorted(entries)
    n = len(entries)
    rng = np.random.default_rng(seed)
    for cap in (n, n + 3, 2 * n + 5):
        slots = rng.choice(cap, size=n, replace=False)
        s = np.full(cap, -np.inf, np.float32)
        d0 = np.zeros(cap, np.int32)
        d1 = np.zeros(cap, np.int32)
        s[slots] = [e[0] for e in entries]
        d0[slots] = [e[1] for e in entries]
        d1[slots] = [e[2] for e in entries]
        got = []
        for _ in range(n):
            j = int(H.lex_argmax(jnp.asarray(s), jnp.asarray(d0),
                                 jnp.asarray(d1), jnp.asarray(s > -np.inf)))
            got.append((float(s[j]), int(d0[j]), int(d1[j])))
            s[j] = -np.inf
        assert got == expect, cap


def test_all_equal_scores_degenerate_pool():
    """Degenerate pool: every score equal — order falls entirely to the
    payload (d0 asc, then d1 desc), for the heap and the pool alike."""
    entries = [(1.0, d0, d1) for d0 in (3, 1, 2, 0) for d1 in (9, 12)]
    expect = [(1.0, d0, d1) for d0 in (0, 1, 2, 3) for d1 in (12, 9)]
    assert _lex_sorted(entries) == expect
    assert _heap_pop_all(entries) == expect
    assert _heap_pop_all(entries, p=4) == expect


# ---------------------------------------------------------------------------
# kernel layer: >= 200-case differential sweep, mega vs serial, bitwise
# ---------------------------------------------------------------------------

SWEEP_COMBOS = [
    ("and", "dr", "tfidf"),
    ("or", "dr", "tfidf"),
    ("and", "drb", "tfidf"),
    ("and", "drb", "bm25"),
    ("or", "drb", "tfidf"),
    ("or", "drb", "bm25"),
]
CASES_PER_COMBO = 35          # 6 x 35 = 210 cases (ISSUE floor: 200)


def test_sweep_meets_case_floor():
    assert len(SWEEP_COMBOS) * CASES_PER_COMBO >= 200


def _sweep_cases(engine_corpus, seed, n_cases, B=4, L=3):
    """n_cases batches of B queries, all L words long — one (B, Q) bucket
    per combo, so every comparison runs at a MATCHED Q bucket (the bitwise
    contract's precondition) and compiles each executor exactly once."""
    df = engine_corpus.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 60))
    rng = np.random.default_rng(seed)
    return [[list(map(int, rng.choice(pool, L, replace=False)))
             for _ in range(B)] for _ in range(n_cases)]


@pytest.mark.parametrize(("mode", "strategy", "measure"),
                         SWEEP_COMBOS,
                         ids=["-".join(c) for c in SWEEP_COMBOS])
def test_differential_sweep_bitwise(engine, engine_corpus, mode, strategy,
                                    measure):
    """Seeded sweep: a mega=True batch equals per-row serial execution
    bitwise — docs, scores, n_found, and (on the DR paths, where the loop
    counters are part of the contract) work/pops/overflowed too.  On DRB
    combos ``mega`` normalizes off, so the sweep pins the lockstep batch
    against serial rows instead — same invariance, different core."""
    seed = 100 + SWEEP_COMBOS.index((mode, strategy, measure))
    cases = _sweep_cases(engine_corpus, seed, CASES_PER_COMBO)
    kw = dict(mode=mode, strategy=strategy, measure=measure, k=8)
    if strategy == "drb" and mode == "or":
        kw["df_cap"] = engine.suggested_df_cap(
            [q for case in cases for q in case])
    for case in cases:
        batched = engine.search(case, mega=True, **kw)
        if strategy == "dr":
            # mega vs lockstep (vmapped heap core): full result, bitwise
            lockstep = engine.search(case, mega=False, **kw)
            for name in ("docs", "scores", "n_found", "work", "pops",
                         "overflowed"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(batched, name)),
                    np.asarray(getattr(lockstep, name)), err_msg=name)
        for b, q in enumerate(case):
            serial = engine.search([q], mega=False, **kw)
            for name in ("docs", "scores", "n_found"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(batched, name)[b]),
                    np.asarray(getattr(serial, name)[0]),
                    err_msg=f"{name} row {b} of {case}")
            if strategy == "dr":
                assert int(batched.work[b]) == int(serial.work[0])
                assert int(batched.pops[b]) == int(serial.pops[0])
                assert not bool(np.asarray(serial.overflowed)[0])


def test_mega_full_cap_never_overflows(engine, query_batch):
    """cap = n_docs + 2 bounds the split-tree frontier: no mega query can
    ever latch overflow at the default capacity."""
    res = engine.search(query_batch, mode="or", strategy="dr", k=10,
                        mega=True)
    assert not np.asarray(res.overflowed).any()
    assert np.asarray(res.n_found).min() > 0


def test_mega_pool_overflow_latched_in_diagnostics():
    """An undersized pool must DROP inserts and latch per-row ``overflowed``
    — surfaced through SearchResults.diagnostics, mirroring the heap's
    contract — never corrupt silently."""
    cp = corpus.make_corpus(n_docs=12, mean_doc_len=20, vocab_size=60, seed=2)
    eng = SearchEngine.build(cp, EngineConfig(block=512))
    df = cp.doc_freqs()
    pool = np.flatnonzero(df >= 4)
    q = list(map(int, pool[pool >= 1][:3]))   # id 0 is the separator
    eng._mega_cap = 2             # root fills slot 0: first split overflows
    res = eng.search([q], mode="or", strategy="dr", k=5, mega=True)
    d = res.diagnostics
    assert d["overflowed"].any()


def test_cross_q_bucket_bm25_drift_is_ulp_bounded(engine, mega_queries):
    """The documented caveat: the SAME query scored in a different Q bucket
    may drift by ~1 ulp (shape-dependent FMA in the BM25 reduction) — but
    no more; and re-running at a MATCHED bucket is bitwise again, which is
    exactly why the sweep above fixes the query length per combo."""
    q3 = mega_queries[0]
    pool = sorted(set(sum(mega_queries, [])))
    heavy5 = [w for w in pool if w not in q3][:5]         # 5 words: bucket 8
    kw = dict(mode="or", strategy="drb", measure="bm25", k=8,
              df_cap=engine.suggested_df_cap([q3, heavy5]))
    a = engine.search([q3, q3], **kw)                    # Q bucket 4
    b = engine.search([q3, heavy5], **kw)                # Q bucket 8
    ra, rb = np.asarray(a.scores)[0], np.asarray(b.scores)[0]
    finite = np.isfinite(ra) & np.isfinite(rb)
    ulp = np.spacing(np.maximum(np.abs(ra[finite]),
                                np.abs(rb[finite])).astype(np.float32))
    assert np.all(np.abs(ra[finite] - rb[finite]) <= 4 * ulp)
    assert int(a.n_found[0]) == int(b.n_found[0])
    # matched bucket, different batch-mates: bitwise, not just close
    c = engine.search([q3, mega_queries[3]], **kw)
    np.testing.assert_array_equal(np.asarray(a.docs)[0], np.asarray(c.docs)[0])
    np.testing.assert_array_equal(ra, np.asarray(c.scores)[0])


# ---------------------------------------------------------------------------
# admission layer: work buckets, heavy lane, starvation bound, adaptive wait
# ---------------------------------------------------------------------------

def test_work_bucket_factor8_boundaries():
    assert [work_bucket(w) for w in (0, 1, 7, 8, 63, 64, 511, 512)] \
        == [0, 0, 0, 1, 1, 2, 2, 3]


def _scripted_batcher(entries, **kw):
    src = queue.Queue()
    for e in entries:
        src.put(e)
    return MicroBatcher(src.get, **kw)


def test_batcher_coalesces_only_within_lane():
    P = QueryProfile()
    A, B = Lane(bucket=0), Lane(bucket=1)
    mb = _scripted_batcher([([1], P, "a1", 0.0, A), ([2], P, "b1", 0.0, B),
                            ([3], P, "a2", 0.0, A), ([4], P, "a3", 0.0, A)],
                           max_batch=8, max_wait_ms=0.0)
    first = mb.next_batch()
    assert first.items == ["a1", "a2", "a3"] and first.lane == A
    second = mb.next_batch()
    assert second.items == ["b1"] and second.lane == B


def test_heavy_lane_cap1_never_coalesces():
    """cap=1 isolates heavy queries even from EACH OTHER — same profile,
    same lane, still one per batch."""
    P = QueryProfile()
    heavy = Lane(bucket=4, cap=1)
    mb = _scripted_batcher([([9], P, i, 0.0, heavy) for i in range(3)],
                           max_batch=8, max_wait_ms=0.0)
    sizes = [mb.next_batch().n_real for _ in range(3)]
    assert sizes == [1, 1, 1]


def test_starvation_bound_oldest_request_leads():
    """The batch always forms around the OLDEST pending request: a heavy
    cap=1 head dispatches alone immediately — lane isolation can reorder
    batch membership but never starve the head of the queue."""
    P = QueryProfile()
    heavy, light = Lane(bucket=4, cap=1), Lane(bucket=0)
    mb = _scripted_batcher(
        [([9], P, "H", 0.0, heavy)] + [([1], P, f"L{i}", 0.0, light)
                                       for i in range(3)],
        max_batch=8, max_wait_ms=0.0)
    assert mb.next_batch().items == ["H"]
    assert mb.next_batch().items == ["L0", "L1", "L2"]


def test_adaptive_wait_tracks_arrival_ewma():
    """EWMA inter-arrival gap: idle stream -> wait collapses to 0 (a lone
    query pays no coalescing tax); bursty stream -> full max_wait again.
    Also covers lane-less 4-tuple producers (normalized to DEFAULT_LANE)."""
    P = QueryProfile()
    src = queue.Queue()
    for i in range(40):
        src.put(([1], P, i, 0.0))            # 4-tuples: lane-less producer
    t = [0.0]
    mb = MicroBatcher(src.get, max_batch=64, max_wait_ms=10.0,
                      adaptive_wait=True, clock=lambda: t[0])
    assert mb.effective_wait() == 0.010      # no signal yet: full budget
    for _ in range(3):                       # sparse: 1s gaps >> max_wait
        t[0] += 1.0
        assert mb._pull(0.0)
    assert mb.effective_wait() == 0.0
    assert mb._pending[0][4] == DEFAULT_LANE
    for _ in range(30):                      # burst: gaps ~0 << max_wait
        t[0] += 1e-4
        assert mb._pull(0.0)
    assert mb.effective_wait() == 0.010


def _df_dummy_engine(delay_s=0.0):
    """Dummy engine exposing the df surface the admission predictor reads:
    word 10 is heavy (df 400 >= heavy_df = 2 * n_docs = 100), all others
    light (df 2)."""
    V = 64
    df = np.full(V, 2, np.int64)
    df[10] = 400

    def search(queries, **kw):
        if delay_s:
            time.sleep(delay_s)
        B, k = len(queries), kw.get("k") or 3
        return types.SimpleNamespace(
            docs=np.tile(np.arange(k, dtype=np.int32), (B, 1)),
            scores=np.zeros((B, k), np.float32),
            n_found=np.full(B, k, np.int32), work=np.ones(B, np.int32),
            pops=None, overflowed=None, match_pos=None, match_len=None,
            k=k, mode=kw.get("mode", "and"), strategy="dr", measure="tfidf")

    return types.SimpleNamespace(
        search=search,
        model=types.SimpleNamespace(vocab_size=V,
                                    rank_of_word=np.arange(V)),
        _df_np=df, n_docs=50,
        stats={"executors": 0, "traces": {}},
        warmup=lambda *a, **kw: 0)


def test_server_isolates_predicted_heavy_queries():
    """End-to-end admission: under a burst, light queries coalesce while
    df-predicted-heavy ones run at batch size 1, never taxing batch-mates."""
    eng = _df_dummy_engine(delay_s=0.03)
    with SearchServer(eng, max_batch=8, max_wait_ms=5.0, queue_depth=64,
                      cache_size=0, work_buckets=True) as server:
        warm = server.submit([1, 2, 3])      # occupies the dispatch thread
        lights = [server.submit([1 + i % 5, 2, 3]) for i in range(6)]
        heavies = [server.submit([10]) for _ in range(2)]
        for t in [warm, *lights, *heavies]:
            t.result(timeout=10.0)
        assert all(t.batch_size == 1 for t in heavies)
        assert max(t.batch_size for t in lights) > 1
        assert server.stats["served"] == 9


# ---------------------------------------------------------------------------
# serving layer: bitwise pins through every frontend feature
# ---------------------------------------------------------------------------

def test_server_mega_lanes_cache_bitwise(engine, mega_queries):
    """The full serving stack at once — mega executor, work buckets,
    adaptive wait, result cache — answers bitwise equal to direct serial
    ``engine.search`` (classical core), and the cache replays identically."""
    profile = QueryProfile(mode="or", strategy="dr", measure="tfidf", k=6,
                           mega=True)
    server = SearchServer(engine, max_batch=4, max_wait_ms=2.0,
                          cache_size=64, work_buckets=True,
                          adaptive_wait=True)
    server.warmup(mega_queries, profile)
    with server:
        tickets = [server.submit(q, profile) for q in mega_queries]
        rows = [t.result(timeout=120.0) for t in tickets]
        for q, row in zip(mega_queries, rows):
            _row_equals(row, engine.search([q], mode="or", strategy="dr",
                                           measure="tfidf", k=6, mega=False))
        replay = server.submit(mega_queries[0], profile)
        assert replay.cache_hit
        _row_equals(replay.result(), engine.search(
            [mega_queries[0]], mode="or", strategy="dr", k=6, mega=False))


def test_swap_engine_retags_cache_and_answers(engine, engine_b, mega_queries):
    """drain -> swap -> clear: pre-swap answers come from (and match) the
    old engine; a post-swap identical query MISSES the version-tagged cache
    and answers bitwise from the new engine."""
    assert engine.content_tag != engine_b.content_tag
    profile = QueryProfile(mode="and", strategy="dr", k=5)
    q = mega_queries[0]
    with SearchServer(engine, max_batch=4, cache_size=64) as server:
        r_old = server.search(q, profile)
        assert server.submit(q, profile).cache_hit
        old = server.swap_engine(engine_b)
        assert old is engine
        st_ = server.stats
        assert st_["swaps"] == 1 and st_["engine_tag"] == engine_b.content_tag
        t = server.submit(q, profile)
        assert not t.cache_hit               # tagged key cannot cross engines
        _row_equals(t.result(timeout=120.0),
                    engine_b.search([q], mode="and", strategy="dr", k=5))
    _row_equals(r_old, engine.search([q], mode="and", strategy="dr", k=5))


def test_swap_engine_drains_inflight_sheds_new():
    """Concurrency contract: a request in flight when the swap starts
    completes against the OLD engine; admissions during the drain shed;
    the first post-swap request answers from the new engine."""
    old_eng, new_eng = _df_dummy_engine(delay_s=0.3), _df_dummy_engine()
    new_eng.search = lambda queries, **kw: types.SimpleNamespace(
        docs=np.full((len(queries), 3), 7, np.int32),
        scores=np.full((len(queries), 3), 2.0, np.float32),
        n_found=np.full(len(queries), 3, np.int32),
        work=np.ones(len(queries), np.int32),
        pops=None, overflowed=None, match_pos=None, match_len=None,
        k=3, mode="and", strategy="dr", measure="tfidf")
    old_eng.content_tag, new_eng.content_tag = 111, 222
    with SearchServer(old_eng, max_batch=1, max_wait_ms=0.0,
                      cache_size=0) as server:
        inflight = server.submit([1])
        deadline = time.monotonic() + 5.0
        while inflight.t_dispatch is None:   # wait until it's on the engine
            assert time.monotonic() < deadline
            time.sleep(0.001)
        swapped = []
        th = threading.Thread(
            target=lambda: swapped.append(server.swap_engine(new_eng)))
        th.start()
        while not server._draining:          # drain must engage (>= 0.3s)
            assert time.monotonic() < deadline
            time.sleep(0.001)
        with pytest.raises(ShedError, match="drain"):
            server.submit([2])
        th.join(timeout=10.0)
        assert swapped == [old_eng]
        assert np.all(inflight.result().scores == 0.0)   # old engine's answer
        assert np.all(server.search([3]).scores == 2.0)  # new engine's answer
        assert server.stats["shed"] == 1


def test_snapshot_restore_serves_mega_bitwise(engine, mega_queries, tmp_path):
    """Snapshot round-trip preserves the content tag AND the mega path:
    a restored engine's mega batch equals the live engine's, bitwise."""
    snapshot.save(engine, tmp_path)
    restored = snapshot.load(tmp_path)
    assert restored.content_tag == engine.content_tag
    batch = mega_queries[:4]
    a = engine.search(batch, mode="or", strategy="dr", k=6, mega=True)
    b = restored.search(batch, mode="or", strategy="dr", k=6, mega=True)
    for name in ("docs", "scores", "n_found", "work", "pops", "overflowed"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def test_snapshot_device_put_is_zero_copy(tmp_path):
    """CPU backend: ``_device_put`` must ALIAS the mmap'd .npy pages (the
    64-byte-aligned payload), not copy them — boot stays O(metadata)."""
    if jax.default_backend() != "cpu":
        pytest.skip("zero-copy aliasing is a CPU-backend contract")
    arr = np.arange(4096, dtype=np.int32)
    np.save(tmp_path / "a.npy", arr)
    m = np.load(tmp_path / "a.npy", mmap_mode="r")
    dev = snapshot._device_put({"a": m})["a"]
    try:
        dev_ptr = dev.unsafe_buffer_pointer()
    except (AttributeError, NotImplementedError):  # pragma: no cover
        pytest.skip("backend exposes no buffer pointer")
    assert dev_ptr == m.ctypes.data              # same pages, no copy
    np.testing.assert_array_equal(np.asarray(dev), arr)
