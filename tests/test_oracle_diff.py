"""Randomized differential tests: every query path vs the NumPy oracle.

The engine answers from the compressed WTBC through jitted kernels; the
oracle (tests/oracle.py) rescans the raw token lists.  Queries run with
``k = n_docs`` so the *full* eligible ranking comes back and comparisons are
per-document — no dependence on tie order.

Two populations:
* deterministic seeded sweeps (always run, no extra deps) — ≥ 200 randomized
  positional cases plus DR/DRB and/or differentials across three corpora;
* hypothesis property tests (via tests/_hypothesis_shim.py — they skip
  cleanly when hypothesis is not installed) over tiny adversarial corpora,
  hitting the core kernels directly so jit caches across examples.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import oracle
from _hypothesis_shim import given, settings, st

from repro.core import positional, ranked, scoring, wtbc
from repro.engine import EngineConfig, SearchEngine
from repro.text import corpus

RTOL, ATOL = 2e-5, 1e-4


# ---------------------------------------------------------------------------
# corpus / query generation
# ---------------------------------------------------------------------------

def make_docs(rng, n_docs, max_len, vocab, min_len=3):
    return [rng.integers(1, vocab, size=int(rng.integers(min_len, max_len + 1))
                         ).astype(np.int64) for _ in range(n_docs)]


def sample_queries(rng, docs, vocab, n_queries, q_len, random_prob=0.4):
    """Query batch mixing document n-grams (guaranteed phrase/window hits)
    with uniform random word combinations (no-match and partial cases)."""
    return corpus.sample_ngram_queries(
        docs, n_queries, q_len, seed=int(rng.integers(2**31)),
        random_prob=random_prob, vocab_size=vocab)


# ---------------------------------------------------------------------------
# comparison helpers
# ---------------------------------------------------------------------------

def assert_positional_matches_oracle(engine, docs, queries, mode, measure,
                                     window=None):
    res = engine.search(queries, k=len(docs), mode=mode, measure=measure,
                        window=window)
    for b in range(len(queries)):
        exp = oracle.search_oracle(docs, queries[b], mode=mode,
                                   measure=measure, window=window,
                                   vocab_size=engine.model.vocab_size)
        got = {d: (s, p, l) for d, s, p, l in res.matches(b)}
        assert set(got) == set(exp), (mode, measure, queries[b].tolist())
        for d, (s, p, l) in got.items():
            assert p == exp[d]["pos"], (mode, d, queries[b].tolist())
            assert l == exp[d]["len"], (mode, d, queries[b].tolist())
            np.testing.assert_allclose(s, exp[d]["score"], rtol=RTOL,
                                       atol=ATOL)
    return len(queries)


def assert_ranked_matches_oracle(engine, docs, queries, mode, strategy,
                                 measure):
    res = engine.search(queries, k=len(docs), mode=mode, strategy=strategy,
                        measure=measure)
    for b in range(len(queries)):
        exp = oracle.search_oracle(docs, queries[b], mode=mode,
                                   measure=measure, strategy=strategy,
                                   vocab_size=engine.model.vocab_size)
        got = dict(res.hits(b))
        assert set(got) == set(exp), (mode, strategy, measure,
                                      queries[b].tolist())
        for d, s in got.items():
            np.testing.assert_allclose(s, exp[d]["score"], rtol=RTOL,
                                       atol=ATOL)
    return len(queries)


# ---------------------------------------------------------------------------
# deterministic seeded sweeps (the ≥ 200-case acceptance gate)
# ---------------------------------------------------------------------------

# (n_docs, max_doc_len, vocab) — small vocabularies force plenty of phrase
# hits, repeated words, and tight proximity windows
CORPORA = [(12, 24, 30), (30, 16, 60), (20, 40, 25)]


@pytest.fixture(scope="module")
def diff_engines():
    out = []
    for seed, (n_docs, max_len, vocab) in enumerate(CORPORA):
        rng = np.random.default_rng(100 + seed)
        docs = make_docs(rng, n_docs, max_len, vocab)
        engine = SearchEngine.build(docs, EngineConfig(block=128),
                                    vocab_size=vocab)
        out.append((rng, docs, vocab, engine))
    return out


def test_positional_differential_200_cases(diff_engines):
    """phrase/near (docs, scores, match positions) == oracle on ≥ 200 cases."""
    cases = 0
    for ci, (rng, docs, vocab, engine) in enumerate(diff_engines):
        B = 20
        q2 = sample_queries(rng, docs, vocab, B, 2)
        q3 = sample_queries(rng, docs, vocab, B, 3)
        cases += assert_positional_matches_oracle(
            engine, docs, q2, "phrase", "tfidf")
        cases += assert_positional_matches_oracle(
            engine, docs, q2, "near", "tfidf", window=3)
        # same executor, different window — dynamic, no retrace
        cases += assert_positional_matches_oracle(
            engine, docs, q2, "near", "tfidf", window=8)
        if ci < 2:   # full matrix on the first two corpora
            cases += assert_positional_matches_oracle(
                engine, docs, q3, "phrase", "tfidf")
            cases += assert_positional_matches_oracle(
                engine, docs, q3, "near", "bm25", window=5)
    assert cases >= 200, cases


def test_ranked_differential_dr_drb(diff_engines):
    """Existing DR/DRB and/or paths against the same oracle."""
    cases = 0
    for rng, docs, vocab, engine in diff_engines[:2]:
        qs = sample_queries(rng, docs, vocab, 8, 2, random_prob=0.6)
        for mode in ("and", "or"):
            for strategy in ("dr", "drb"):
                cases += assert_ranked_matches_oracle(
                    engine, docs, qs, mode, strategy, "tfidf")
            cases += assert_ranked_matches_oracle(
                engine, docs, qs, mode, "drb", "bm25")
    assert cases >= 90, cases


def test_phrase_with_duplicate_words():
    """Repeated-word phrases ("w w") exercise the decode adjacency check."""
    # force documents that contain runs
    run_docs = [np.array([5, 5, 7, 5, 5, 5, 2], dtype=np.int64),
                np.array([5, 7, 5, 7, 5], dtype=np.int64),
                np.array([7, 7, 2, 2, 2], dtype=np.int64)]
    eng = SearchEngine.build(run_docs, EngineConfig(block=128), vocab_size=10)
    for q in ([5, 5], [5, 5, 5], [7, 5], [2, 2]):
        exp = oracle.search_oracle(run_docs, q, mode="phrase",
                                   measure="tfidf", vocab_size=10)
        res = eng.search([q], k=3, mode="phrase")
        got = {d: (p, l) for d, _, p, l in res.matches(0)}
        assert got == {d: (v["pos"], v["len"]) for d, v in exp.items()}, q


# ---------------------------------------------------------------------------
# hypothesis property tests (skip without the dev extra)
# ---------------------------------------------------------------------------

N_DOCS_H, VOCAB_H, BLOCK_H = 6, 12, 128

docs_strategy = st.lists(
    st.lists(st.integers(min_value=1, max_value=VOCAB_H - 1),
             min_size=3, max_size=10),
    min_size=N_DOCS_H, max_size=N_DOCS_H)
query2 = st.lists(st.integers(min_value=1, max_value=VOCAB_H - 1),
                  min_size=2, max_size=2)
query3 = st.lists(st.integers(min_value=1, max_value=VOCAB_H - 1),
                  min_size=3, max_size=3)


def _index(doc_lists):
    docs = [np.asarray(d, dtype=np.int64) for d in doc_lists]
    idx, model = wtbc.build_index(docs, VOCAB_H, block=BLOCK_H)
    return docs, idx, model


@settings(max_examples=25, deadline=None)
@given(doc_lists=docs_strategy, q=query2)
def test_hyp_phrase_matches_oracle(doc_lists, q):
    docs, idx, model = _index(doc_lists)
    m = scoring.TfIdf()
    words = jnp.asarray(model.rank_of_word[np.asarray(q)], jnp.int32)
    res = positional.topk_positional(idx, words, jnp.ones(2, bool), m.idf(idx),
                                     k=N_DOCS_H, phrase=True, measure=m)
    exp = oracle.search_oracle(docs, q, mode="phrase", measure="tfidf",
                               vocab_size=VOCAB_H)
    n = int(res.n_found)
    got = {int(d): (float(s), int(p), int(l)) for d, s, p, l in zip(
        np.asarray(res.docs)[:n], np.asarray(res.scores)[:n],
        np.asarray(res.match_pos)[:n], np.asarray(res.match_len)[:n])}
    assert set(got) == set(exp)
    for d, (s, p, l) in got.items():
        assert (p, l) == (exp[d]["pos"], exp[d]["len"])
        np.testing.assert_allclose(s, exp[d]["score"], rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(doc_lists=docs_strategy, q=query3,
       window=st.integers(min_value=1, max_value=8))
def test_hyp_near_matches_oracle(doc_lists, q, window):
    docs, idx, model = _index(doc_lists)
    m = scoring.TfIdf()
    words = jnp.asarray(model.rank_of_word[np.asarray(q)], jnp.int32)
    res = positional.topk_positional(idx, words, jnp.ones(3, bool), m.idf(idx),
                                     k=N_DOCS_H, phrase=False, measure=m,
                                     window=jnp.int32(window))
    exp = oracle.search_oracle(docs, q, mode="near", measure="tfidf",
                               window=window, vocab_size=VOCAB_H)
    n = int(res.n_found)
    got = {int(d): (float(s), int(p), int(l)) for d, s, p, l in zip(
        np.asarray(res.docs)[:n], np.asarray(res.scores)[:n],
        np.asarray(res.match_pos)[:n], np.asarray(res.match_len)[:n])}
    assert set(got) == set(exp)
    for d, (s, p, l) in got.items():
        assert (p, l) == (exp[d]["pos"], exp[d]["len"])
        np.testing.assert_allclose(s, exp[d]["score"], rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(doc_lists=docs_strategy, q=query2, conjunctive=st.booleans())
def test_hyp_dr_matches_oracle(doc_lists, q, conjunctive):
    docs, idx, model = _index(doc_lists)
    m = scoring.TfIdf()
    words = jnp.asarray(model.rank_of_word[np.asarray(q)], jnp.int32)
    res = ranked.topk_dr(idx, words, jnp.ones(2, bool), m.idf(idx),
                         k=N_DOCS_H, conjunctive=conjunctive,
                         heap_cap=2 * N_DOCS_H + 4)
    exp = oracle.search_oracle(docs, q, mode="and" if conjunctive else "or",
                               measure="tfidf", strategy="dr",
                               vocab_size=VOCAB_H)
    n = int(res.n_found)
    got = {int(d): float(s) for d, s in zip(np.asarray(res.docs)[:n],
                                            np.asarray(res.scores)[:n])}
    assert set(got) == set(exp)
    for d, s in got.items():
        np.testing.assert_allclose(s, exp[d]["score"], rtol=RTOL, atol=ATOL)
