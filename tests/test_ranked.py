"""WTBC-DR (Algorithm 1) vs brute-force tf-idf oracle.

Scores are compared as sorted vectors (heap pop order among *tied* scores is
unspecified, exactly as in the paper); documents strictly above the k-th
score must match as sets.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import ranked, wtbc


def check_topk_equal(bf, dr, atol=1e-4):
    assert int(bf.n_found) == int(dr.n_found)
    bs = np.sort(np.asarray(bf.scores))[::-1]
    ds = np.sort(np.asarray(dr.scores))[::-1]
    assert np.allclose(bs, ds, atol=atol), (bs, ds)
    # docs strictly above the k-th score are uniquely determined
    kth = bs[int(bf.n_found) - 1] if int(bf.n_found) else -np.inf
    bf_docs = {int(d) for d, s in zip(np.asarray(bf.docs), np.asarray(bf.scores))
               if s > kth + atol}
    dr_docs = {int(d) for d, s in zip(np.asarray(dr.docs), np.asarray(dr.scores))
               if s > kth + atol}
    assert bf_docs == dr_docs


def query_pool(idx, rng, q):
    df = np.asarray(idx.df)
    pool = np.flatnonzero((df >= 2) & (df <= int(idx.n_docs) // 2))
    return rng.choice(pool, size=q, replace=False)


@pytest.mark.parametrize("conjunctive", [True, False])
def test_dr_matches_bruteforce(small_index, tfidf, conjunctive):
    idx, model = small_index
    idf = tfidf.idf(idx)
    N = int(idx.n_docs)
    rng = np.random.default_rng(7)
    for trial in range(5):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.ones(3, bool)
        bf = ranked.topk_bruteforce(idx, words, wmask, idf, k=10,
                                    conjunctive=conjunctive)
        dr = ranked.topk_dr(idx, words, wmask, idf, k=10,
                            conjunctive=conjunctive, heap_cap=2 * N + 4)
        check_topk_equal(bf, dr)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dr_matches_bruteforce_property(small_index, tfidf, seed):
    idx, model = small_index
    idf = tfidf.idf(idx)
    N = int(idx.n_docs)
    rng = np.random.default_rng(seed)
    words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
    wmask = jnp.asarray(rng.random(3) < 0.9)
    if not bool(wmask.any()):
        return
    for conj in (True, False):
        bf = ranked.topk_bruteforce(idx, words, wmask, idf, k=10,
                                    conjunctive=conj)
        dr = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=conj,
                            heap_cap=2 * N + 4)
        check_topk_equal(bf, dr)


def test_dr_emission_order_descending(small_index, tfidf):
    idx, _ = small_index
    idf = tfidf.idf(idx)
    rng = np.random.default_rng(3)
    words = jnp.asarray(query_pool(idx, rng, 2), jnp.int32)
    dr = ranked.topk_dr(idx, words, jnp.ones(2, bool), idf, k=15,
                        conjunctive=False, heap_cap=2 * int(idx.n_docs) + 4)
    s = np.asarray(dr.scores)[: int(dr.n_found)]
    assert (np.diff(s) <= 1e-5).all()      # emitted most-relevant-first


def test_dr_anytime_budget_certified(small_index, tfidf):
    """max_pops budget (DESIGN.md §11): the *certified* slots are a prefix
    and equal the exact ranking exactly; the score bound caps everything
    the budget cut off; a never-binding budget is bitwise exact."""
    idx, _ = small_index
    idf = tfidf.idf(idx)
    rng = np.random.default_rng(5)
    words = jnp.asarray(query_pool(idx, rng, 2), jnp.int32)
    wmask = jnp.ones(2, bool)
    cap = 2 * int(idx.n_docs) + 4
    full = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                          heap_cap=cap)
    assert int(np.asarray(full.certified).sum()) == int(full.n_found)
    budget = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                            heap_cap=cap, max_pops=int(full.iters) // 2)
    cert = np.asarray(budget.certified)
    assert not np.any(np.diff(cert.astype(int)) > 0)      # prefix property
    nc = int(cert.sum())
    np.testing.assert_array_equal(np.asarray(budget.docs)[:nc],
                                  np.asarray(full.docs)[:nc])
    np.testing.assert_array_equal(np.asarray(budget.scores)[:nc],
                                  np.asarray(full.scores)[:nc])
    # returned slots stay best-first; the bound caps every absent doc
    nb = int(budget.n_found)
    s = np.asarray(budget.scores)[:nb]
    assert (np.diff(s) <= 1e-6).all()
    got = set(np.asarray(budget.docs)[:nb].tolist())
    bound = float(budget.bound)
    for d, sc in zip(np.asarray(full.docs), np.asarray(full.scores)):
        if d >= 0 and int(d) not in got:
            assert sc <= bound + 1e-6
    # a budget that never binds changes nothing (bitwise)
    nb2 = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                         heap_cap=cap, max_pops=2 * int(idx.n_docs) + 2)
    np.testing.assert_array_equal(np.asarray(full.docs), np.asarray(nb2.docs))
    np.testing.assert_array_equal(np.asarray(full.scores),
                                  np.asarray(nb2.scores))
    assert int(np.asarray(nb2.certified).sum()) == int(nb2.n_found)


def test_dr_batch_vmap(small_index, tfidf):
    idx, _ = small_index
    idf = tfidf.idf(idx)
    rng = np.random.default_rng(9)
    words = jnp.asarray(np.stack([query_pool(idx, rng, 2) for _ in range(4)]),
                        jnp.int32)
    wmask = jnp.ones((4, 2), bool)
    res = ranked.topk_dr_batch(idx, words, wmask, idf, k=5, conjunctive=False,
                               heap_cap=2 * int(idx.n_docs) + 4)
    assert res.docs.shape == (4, 5)
    for b in range(4):
        bf = ranked.topk_bruteforce(idx, words[b], wmask[b], idf, k=5,
                                    conjunctive=False)
        assert np.allclose(np.sort(np.asarray(bf.scores)),
                           np.sort(np.asarray(res.scores[b])), atol=1e-4)
