"""Pallas kernels vs ref.py oracles — shape/dtype sweeps, interpret=True."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import bitvec, bytemap
from repro.kernels import ops, ref


@pytest.mark.parametrize("n,block", [(100, 256), (4096, 512), (9000, 512),
                                     (9000, 4096), (70000, 8192)])
def test_byte_rank_shapes(n, block):
    rng = np.random.default_rng(n + block)
    data = rng.integers(0, 256, n).astype(np.uint8)
    bm = bytemap.build(data, block=block)
    B = 17
    bq = jnp.asarray(rng.integers(0, 256, B), jnp.int32)
    pq = jnp.asarray(rng.integers(0, n + 1, B), jnp.int32)
    got = np.asarray(ops.rank_batch(bm, bq, pq))
    want = np.asarray(ref.byte_rank_ref(bm.data, bm.counts, bm.length, bq, pq,
                                        block=block))
    direct = np.array([bytemap.rank_np(data, int(b), int(p))
                       for b, p in zip(np.asarray(bq), np.asarray(pq))])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, direct)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 20000))
def test_byte_rank_property(seed, n):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 8, n).astype(np.uint8)   # dense hits
    bm = bytemap.build(data, block=512)
    bq = jnp.asarray(rng.integers(0, 8, 9), jnp.int32)
    pq = jnp.asarray(rng.integers(0, n + 1, 9), jnp.int32)
    got = np.asarray(ops.rank_batch(bm, bq, pq))
    direct = np.array([bytemap.rank_np(data, int(b), int(p))
                       for b, p in zip(np.asarray(bq), np.asarray(pq))])
    np.testing.assert_array_equal(got, direct)


@pytest.mark.parametrize("n_bits", [100, 1024, 5000, 70000])
def test_bitmap_rank1(n_bits):
    rng = np.random.default_rng(n_bits)
    set_bits = np.unique(rng.integers(0, n_bits, max(1, n_bits // 3)))
    bv = bitvec.build(set_bits, n_bits)
    pq = jnp.asarray(rng.integers(0, n_bits + 1, 23), jnp.int32)
    got = np.asarray(ops.bitmap_rank1_batch(bv, pq))
    want = np.array([bitvec.rank1_np(set_bits, int(p)) for p in np.asarray(pq)])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("C,d,k,tile,dtype", [
    (1000, 128, 5, 256, np.float32),
    (5000, 128, 10, 512, np.float32),
    (4096, 256, 16, 1024, np.float32),
    (3000, 128, 8, 512, np.float16),     # dtype sweep (cast to f32 inside)
    (1537, 128, 4, 512, np.float32),     # non-multiple of tile (padding path)
])
def test_scored_topk(C, d, k, tile, dtype):
    rng = np.random.default_rng(C + k)
    cands = rng.standard_normal((C, d)).astype(dtype)
    q = rng.standard_normal(d).astype(dtype)
    s_k, i_k = ops.scored_topk(jnp.asarray(cands), jnp.asarray(q), k=k, tile=tile)
    s_r, i_r = ref.scored_topk_ref(jnp.asarray(cands), jnp.asarray(q), k=k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=2e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


def test_kernel_disable_switch(small_index):
    """ops.use_kernels(False) routes to the oracle — results identical."""
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, 2000).astype(np.uint8)
    bm = bytemap.build(data, block=256)
    bq = jnp.asarray(rng.integers(0, 256, 7), jnp.int32)
    pq = jnp.asarray(rng.integers(0, 2001, 7), jnp.int32)
    a = np.asarray(ops.rank_batch(bm, bq, pq))
    with ops.use_kernels(False):
        b = np.asarray(ops.rank_batch(bm, bq, pq))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# randomized kernel/oracle A/B parity at edge positions (0, length, block
# boundaries) — use_kernels(True) interpret-mode vs use_kernels(False)
# ---------------------------------------------------------------------------

def edge_positions(rng, n, block, m):
    """Query positions biased to the rank/select edge cases."""
    pos = rng.integers(0, n + 1, m)
    edges = np.array([0, 1, n - 1, n, block - 1, block, block + 1,
                      2 * block, n - block], dtype=np.int64)
    pos[: len(edges)] = np.clip(edges, 0, n)
    return pos


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ab_parity_byte_rank(seed):
    rng = np.random.default_rng(100 + seed)
    n, block = int(rng.integers(700, 6000)), 512
    data = rng.integers(0, 12, n).astype(np.uint8)
    bm = bytemap.build(data, block=block)
    bq = jnp.asarray(rng.integers(0, 12, 24), jnp.int32)
    pq = jnp.asarray(edge_positions(rng, n, block, 24), jnp.int32)
    with ops.use_kernels(True):
        a = np.asarray(ops.rank_batch(bm, bq, pq))
    with ops.use_kernels(False):
        b = np.asarray(ops.rank_batch(bm, bq, pq))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ab_parity_bitmap_rank(seed):
    rng = np.random.default_rng(200 + seed)
    n_bits = int(rng.integers(300, 9000))
    set_bits = np.unique(rng.integers(0, n_bits, max(1, n_bits // 4)))
    bv = bitvec.build(set_bits, n_bits)
    block_bits = bitvec.WORDS_PER_BLOCK * 32
    pq = jnp.asarray(edge_positions(rng, n_bits, block_bits, 24), jnp.int32)
    with ops.use_kernels(True):
        a = np.asarray(ops.bitmap_rank1_batch(bv, pq))
    with ops.use_kernels(False):
        b = np.asarray(ops.bitmap_rank1_batch(bv, pq))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", [0, 1])
def test_ab_parity_topk_score(seed):
    rng = np.random.default_rng(300 + seed)
    C = int(rng.integers(900, 2500))
    cands = rng.standard_normal((C, 128)).astype(np.float32)
    q = rng.standard_normal(128).astype(np.float32)
    with ops.use_kernels(True):
        s_a, i_a = ops.scored_topk(jnp.asarray(cands), jnp.asarray(q), k=8,
                                   tile=512)
    with ops.use_kernels(False):
        s_b, i_b = ops.scored_topk(jnp.asarray(cands), jnp.asarray(q), k=8)
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), rtol=2e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ab_parity_wavelet_descent(small_index, seed):
    """Fused descent kernel (interpret) == batched oracle == scalar walk,
    including lo/hi at 0, n, and counter-block boundaries."""
    from repro.core import wtbc
    from repro.kernels import wavelet_descent as wd

    idx, _ = small_index
    block = idx.levels[0].block
    n = int(idx.n)
    rng = np.random.default_rng(400 + seed)
    M = 32
    words = jnp.asarray(rng.integers(1, idx.vocab_size, M), jnp.int32)
    a = edge_positions(rng, n, block, M)
    b = edge_positions(rng, n, block, M)[::-1].copy()
    lo = jnp.asarray(np.minimum(a, b), jnp.int32)
    hi = jnp.asarray(np.maximum(a, b), jnp.int32)
    kern = np.asarray(wd.wavelet_descent(
        idx.levels, idx.cw, idx.cw_len, idx.node_off, idx.base_rank,
        words, lo, hi, block=block, interpret=True))
    orac = np.asarray(ref.wavelet_count_ref(
        idx.levels, idx.cw, idx.cw_len, idx.node_off, idx.base_rank,
        words, lo, hi))
    scalar = np.array([int(wtbc.count_range(idx, words[i], lo[i], hi[i]))
                       for i in range(M)])
    np.testing.assert_array_equal(kern, orac)
    np.testing.assert_array_equal(kern, scalar)


@pytest.mark.parametrize("plan", ["tpu:interpret", "gpu:interpret"])
def test_wavelet_dispatch_wiring(small_index, plan):
    """Each accelerator branch of ops.wavelet_count_batch passes the index
    tables in the kernel's argument order (on CPU neither branch runs by
    default; ``force_plan`` pins the lowering and interpret executes it)."""
    from repro.core import wtbc
    from repro.kernels import backend

    idx, _ = small_index
    rng = np.random.default_rng(7)
    words = jnp.asarray(rng.integers(1, idx.vocab_size, 9), jnp.int32)
    lo = jnp.zeros(9, jnp.int32)
    hi = jnp.asarray(rng.integers(0, int(idx.n) + 1, 9), jnp.int32)
    want = np.asarray(ref.wavelet_count_ref(
        idx.levels, idx.cw, idx.cw_len, idx.node_off, idx.base_rank,
        words, lo, hi))

    with backend.force_plan(plan):
        got = np.asarray(wtbc.count_range_batch(idx, words, lo, hi))
    np.testing.assert_array_equal(got, want)


def test_segment_tf_kernel():
    rng = np.random.default_rng(5)
    data = rng.integers(0, 16, 20000).astype(np.uint8)
    bm = bytemap.build(data, block=1024)
    bounds = np.sort(rng.choice(20001, size=33, replace=False)).astype(np.int32)
    for byte in (0, 7, 15):
        got = np.asarray(ops.segment_tf_batch(bm, jnp.int32(byte),
                                              jnp.asarray(bounds)))
        want = np.array([(data[a:b] == byte).sum()
                         for a, b in zip(bounds[:-1], bounds[1:])])
        np.testing.assert_array_equal(got, want)
