"""Packed bitvector rank1/select1 vs oracles (property-based + fixed cases).

The property test needs ``hypothesis`` (a dev extra, see pyproject.toml); via
``_hypothesis_shim`` it is skipped — not errored — where the package is
absent, and a deterministic fixed-case sweep keeps rank/select covered there.
"""
import jax.numpy as jnp
import numpy as np

from _hypothesis_shim import given, settings, st
from repro.core import bitvec


def _check_rank_select(seed, n_bits, density):
    rng = np.random.default_rng(seed)
    n_set = int(n_bits * density)
    set_bits = np.sort(rng.choice(n_bits, size=min(n_set, n_bits), replace=False))
    bv = bitvec.build(set_bits, n_bits)
    for _ in range(8):
        p = int(rng.integers(0, n_bits + 1))
        assert int(bitvec.rank1(bv, jnp.int32(p))) == bitvec.rank1_np(set_bits, p)
    total = len(set_bits)
    for j in ([1, total // 2, total, total + 1] if total else [1]):
        if j < 1:
            continue
        assert int(bitvec.select1(bv, jnp.int32(j))) == \
            bitvec.select1_np(set_bits, j, n_bits)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 20000), st.floats(0.0, 1.0))
def test_rank1_select1(seed, n_bits, density):
    _check_rank_select(seed, n_bits, density)


def test_rank1_select1_fixed_cases():
    """Deterministic sweep so rank/select stay covered without hypothesis."""
    for seed, n_bits, density in [(0, 1, 0.0), (1, 1, 1.0), (2, 33, 0.5),
                                  (3, 1024, 0.1), (4, 20000, 0.9),
                                  (5, 2049, 1.0)]:
        _check_rank_select(seed, n_bits, density)


def test_word_boundaries():
    # bits exactly at 32-bit word and 1024-bit block boundaries
    set_bits = np.array([0, 31, 32, 1023, 1024, 2047])
    bv = bitvec.build(set_bits, 2048)
    assert int(bitvec.rank1(bv, jnp.int32(32))) == 2
    assert int(bitvec.rank1(bv, jnp.int32(33))) == 3
    assert int(bitvec.rank1(bv, jnp.int32(1024))) == 4
    assert int(bitvec.select1(bv, jnp.int32(5))) == 1024
    assert int(bitvec.select1(bv, jnp.int32(6))) == 2047
