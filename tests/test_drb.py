"""WTBC-DRB (bitmaps) vs brute-force oracles — tf-idf and BM25."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import drb, ranked, scoring, wtbc
from tests.test_ranked import check_topk_equal, query_pool


def bruteforce_measure(idx, words, wmask, measure, k, conjunctive):
    """Generic oracle for any additive measure (incl. BM25)."""
    idf = measure.idf(idx)
    avg_dl = jnp.sum(idx.doc_len.astype(jnp.float32)) / idx.n_docs
    idf_w = jnp.where(wmask, idf[words], 0.0)

    def score_doc(d):
        lo, hi = wtbc.segment_extent(idx, d, d + 1)
        tf = ranked.count_words_range(idx, words, lo, hi) * wmask
        s = measure.score(tf, idf_w, idx.doc_len[d], avg_dl)
        ok = jnp.all((tf > 0) | ~wmask) & jnp.any(wmask) if conjunctive \
            else jnp.any(tf * wmask > 0)
        return jnp.where(ok, s, -jnp.inf)

    scores = jax.lax.map(score_doc, jnp.arange(int(idx.n_docs), dtype=jnp.int32))
    s, d = jax.lax.top_k(scores, k)
    found = jnp.sum(s > -jnp.inf).astype(jnp.int32)
    return ranked.DRResult(jnp.where(s > -jnp.inf, d, -1).astype(jnp.int32),
                           s, found, jnp.int32(0))


@pytest.mark.parametrize("conjunctive", [True, False])
def test_drb_matches_bruteforce_tfidf(small_index, small_aux, tfidf, conjunctive):
    idx, model = small_index
    rng = np.random.default_rng(17)
    for trial in range(4):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.ones(3, bool)
        bf = ranked.topk_bruteforce(idx, words, wmask, tfidf.idf(idx), k=10,
                                    conjunctive=conjunctive)
        if conjunctive:
            res = drb.topk_drb_and(idx, small_aux, words, wmask, tfidf, k=10)
        else:
            cap = int(np.asarray(idx.df)[np.asarray(words)].max()) + 2
            res = drb.topk_drb_or(idx, small_aux, words, wmask, tfidf, k=10,
                                  max_df_cap=cap)
        check_topk_equal(bf, res)


@pytest.mark.parametrize("conjunctive", [True, False])
def test_drb_bm25(small_index, small_aux, conjunctive):
    """Paper §5: DRB 'easily generalizes' to BM25 — verify it is exact."""
    idx, model = small_index
    bm25 = scoring.BM25()
    rng = np.random.default_rng(23)
    for trial in range(3):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.ones(3, bool)
        bf = bruteforce_measure(idx, words, wmask, bm25, 10, conjunctive)
        if conjunctive:
            res = drb.topk_drb_and(idx, small_aux, words, wmask, bm25, k=10)
        else:
            cap = int(np.asarray(idx.df)[np.asarray(words)].max()) + 2
            res = drb.topk_drb_or(idx, small_aux, words, wmask, bm25, k=10,
                                  max_df_cap=cap)
        check_topk_equal(bf, res)


def test_bm25_requires_drb():
    with pytest.raises(ValueError):
        scoring.assert_dr_compatible(scoring.BM25())
    scoring.assert_dr_compatible(scoring.TfIdf())   # no raise


def test_drb_absent_word_empties_conjunction(small_index, small_aux, tfidf):
    idx, model = small_index
    df = np.asarray(idx.df)
    absent = int(np.flatnonzero(df == 0)[0]) if (df == 0).any() else None
    if absent is None:
        pytest.skip("corpus uses every vocabulary word")
    present = int(np.flatnonzero(df >= 3)[0])
    words = jnp.asarray([present, absent], jnp.int32)
    res = drb.topk_drb_and(idx, small_aux, words, jnp.ones(2, bool), tfidf, k=5)
    assert int(res.n_found) == 0


def test_drb_bitmap_semantics(small_index, small_aux, small_corpus):
    """1-runs in a word's bitmap equal its per-doc term frequencies."""
    idx, model = small_index
    rng = np.random.default_rng(31)
    ranks_by_doc = [model.rank_of_word[d] for d in small_corpus.doc_tokens]
    df = np.asarray(idx.df)
    w = int(rng.choice(np.flatnonzero((df >= 2) & (df <= 20))))
    # oracle: (doc, tf) pairs in doc order
    want = [(d, int((r == w).sum())) for d, r in enumerate(ranks_by_doc)
            if (r == w).any()]
    # from the bitmap: j-th 1 position and gap to the next
    occ = int(np.asarray(drb.word_occ(small_aux, jnp.int32(w))))
    got = []
    for j in range(1, len(want) + 1):
        i_j = int(drb.word_select1(small_aux, jnp.int32(w), jnp.int32(j)))
        i_next = int(drb.word_select1(small_aux, jnp.int32(w), jnp.int32(j + 1)))
        tf = (i_next if j < len(want) else occ) - i_j
        p = int(wtbc.locate(idx, jnp.int32(w), jnp.int32(i_j + 1)))
        d = int(wtbc.doc_of_pos(idx, jnp.int32(p)))
        got.append((d, tf))
    assert got == want
