"""Anytime deadline-bounded search (DESIGN.md §11) — facade-level contract.

* **no-budget invariance**: searches without anytime knobs are bitwise
  identical to a run with a budget too large to bind (and the engine
  normalizes such a budget onto the very same compiled executor);
* **certified = oracle**: a 200+-case differential sweep where budgets DO
  bind — every certified slot equals the exact oracle slot, certified bits
  form a prefix, the score bound caps everything absent;
* **deadline -> budget**: the live us/pop estimate converts wall deadlines
  into pow-4-bucketed pop budgets (drift never recompiles), sla='exact'
  rejects every anytime knob;
* **sharded budgets**: the per-shard budget threads through
  ``distributed_topk`` and the merged result carries global certification.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed
from repro.engine import EngineConfig, SearchEngine
from repro.engine.facade import DEFAULT_US_PER_POP, budget_bucket


def test_budget_bucket_pow4_floor():
    assert [budget_bucket(n) for n in (1, 2, 3, 4, 5, 15, 16, 63, 64, 1000)] \
        == [1, 1, 1, 4, 4, 4, 16, 16, 64, 256]


@pytest.fixture(scope="module")
def wide_batch(engine_corpus):
    """35 rows x 3 words — one batched call covers 35 sweep cases."""
    df = engine_corpus.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 40))
    rng = np.random.default_rng(11)
    return np.stack([rng.choice(pool, 3, replace=False) for _ in range(35)])


def test_no_budget_bitwise_and_executor_reuse(engine, wide_batch):
    """A never-binding budget is normalized off: bitwise-equal answers AND
    the same compiled executor (no key split) as the plain exact search."""
    before = engine.stats["executors"]
    exact = engine.search(wide_batch, k=8, mode="or")
    mid = engine.stats["executors"]
    huge = engine.search(wide_batch, k=8, mode="or", budget=10 ** 9)
    assert engine.stats["executors"] == mid    # reused the exact program
    for name in ("docs", "scores", "n_found"):
        np.testing.assert_array_equal(np.asarray(getattr(exact, name)),
                                      np.asarray(getattr(huge, name)))
    assert exact.sla == "exact" and huge.sla == "bounded"
    assert exact.certified is not None
    assert int(np.asarray(exact.certified).sum()) == \
        int(np.asarray(exact.n_found).sum())
    del before


@pytest.mark.parametrize("mode", ["and", "or"])
@pytest.mark.parametrize("budget", [4, 16, 64])
def test_certified_matches_oracle_sweep(engine, wide_batch, mode, budget):
    """The differential sweep: 35 rows x 3 budgets x 2 modes = 210 cases.
    Wherever the budget binds, certified slots must equal the exact oracle's
    slots bitwise; uncertified tails must respect the score bound."""
    exact = engine.search(wide_batch, k=8, mode=mode)
    res = engine.search(wide_batch, k=8, mode=mode, budget=budget)
    assert res.certified is not None and res.score_bound is not None
    cert = np.asarray(res.certified)
    bound = np.asarray(res.score_bound)
    for b in range(len(wide_batch)):
        assert not np.any(np.diff(cert[b].astype(int)) > 0), b   # prefix
        nc = int(cert[b].sum())
        np.testing.assert_array_equal(np.asarray(res.docs[b])[:nc],
                                      np.asarray(exact.docs[b])[:nc])
        np.testing.assert_array_equal(np.asarray(res.scores[b])[:nc],
                                      np.asarray(exact.scores[b])[:nc])
        nb = int(res.n_found[b])
        got = set(np.asarray(res.docs[b])[:nb].tolist())
        for d, sc in zip(np.asarray(exact.docs[b]),
                         np.asarray(exact.scores[b])):
            if d >= 0 and int(d) not in got:
                assert sc <= bound[b] + 1e-6, (b, d, sc, bound[b])


def test_drb_and_budget_all_or_nothing(engine, wide_batch):
    """DRB/AND visits candidates in doc order -> certification is all-or-
    nothing: complete rows fully certified, cut rows fully uncertified with
    a +inf bound (an unexamined candidate may score anything)."""
    exact = engine.search(wide_batch, k=8, mode="and", strategy="drb")
    res = engine.search(wide_batch, k=8, mode="and", strategy="drb", budget=3)
    cert = np.asarray(res.certified)
    bound = np.asarray(res.score_bound)
    cut = np.asarray(res.pops) < np.asarray(exact.pops)
    for b in range(len(wide_batch)):
        if cut[b]:
            assert not cert[b].any() and bound[b] == np.inf
        else:
            filled = np.asarray(res.scores[b]) > -np.inf
            np.testing.assert_array_equal(cert[b], filled)
            assert bound[b] == -np.inf


def test_deadline_converts_via_estimator(engine, wide_batch):
    """deadline_ms -> pow-4 pop budget at the live us/pop estimate; updates
    to the estimate within a bucket never split the executor key."""
    eng = SearchEngine.build([np.arange(1, 40)] * 50)   # private estimator
    assert eng.us_per_pop == DEFAULT_US_PER_POP
    # 0.4ms at 50us/pop = 8 pops -> bucket 4
    assert eng.budget_for_deadline(0.4) == 4
    eng.note_cost(1e-3, 100.0)                          # 10us/pop
    assert eng.us_per_pop == pytest.approx(10.0)
    # 0.4ms at 10us/pop = 40 pops -> bucket 16
    assert eng.budget_for_deadline(0.4) == 16
    # drift within a bucket: 9.8us/pop -> 40 pops -> still bucket 16
    eng.note_cost(0.9e-3, 100.0)
    assert eng.us_per_pop == pytest.approx(9.8)
    assert eng.budget_for_deadline(0.4) == 16
    # affordable exhaustive search -> None (no executor split)
    assert eng.budget_for_deadline(60_000) is None
    res = engine.search(wide_batch, k=8, mode="or", deadline_ms=60_000)
    assert res.sla == "bounded"
    exact = engine.search(wide_batch, k=8, mode="or")
    np.testing.assert_array_equal(np.asarray(res.docs),
                                  np.asarray(exact.docs))


def test_sla_validation(engine, wide_batch):
    with pytest.raises(ValueError, match="exact"):
        engine.search(wide_batch, k=5, sla="exact", budget=9)
    with pytest.raises(ValueError, match="exact"):
        engine.search(wide_batch, k=5, sla="exact", deadline_ms=5.0)
    with pytest.raises(ValueError, match="sla"):
        engine.search(wide_batch, k=5, sla="turbo")
    with pytest.raises(ValueError, match="deadline_ms"):
        engine.search(wide_batch, k=5, deadline_ms=0.0)
    with pytest.raises(ValueError, match="deadline_ms"):
        engine.search(wide_batch, k=5, mode="phrase", deadline_ms=5.0)
    with pytest.raises(ValueError, match="default_sla"):
        EngineConfig(default_sla="fastest")
    res = engine.search(wide_batch, k=5, mode="or", budget=16,
                        sla="best_effort")
    assert res.sla == "best_effort"


def test_sharded_budget_threads_through(small_corpus):
    """Per-shard anytime budget on the sharded backend (1-shard CPU mesh):
    merged results carry global certified bits + bound; certified slots
    match the single-host exact oracle."""
    sharded, model = distributed.build_sharded(
        small_corpus.doc_tokens, small_corpus.vocab_size, n_shards=1,
        block=512)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    df = small_corpus.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 40))
    rng = np.random.default_rng(21)
    words = jnp.asarray(rng.choice(pool, 3, replace=False), jnp.int32)[None]
    wmask = jnp.ones((1, 3), bool)
    exact = distributed.distributed_topk(
        sharded, words, wmask, k=8, method="dr-or", mesh=mesh,
        shard_axes="shards")
    assert exact.certified is not None
    res = distributed.distributed_topk(
        sharded, words, wmask, k=8, method="dr-or", mesh=mesh,
        shard_axes="shards", max_pops=8)
    cert = np.asarray(res.certified)[0]
    assert not np.any(np.diff(cert.astype(int)) > 0)
    nc = int(cert.sum())
    np.testing.assert_array_equal(np.asarray(res.docs)[0][:nc],
                                  np.asarray(exact.docs)[0][:nc])
    # never-binding per-shard budget: same docs/scores as exact
    nb = distributed.distributed_topk(
        sharded, words, wmask, k=8, method="dr-or", mesh=mesh,
        shard_axes="shards", max_pops=10 ** 6)
    np.testing.assert_array_equal(np.asarray(exact.docs), np.asarray(nb.docs))
    np.testing.assert_array_equal(np.asarray(exact.scores),
                                  np.asarray(nb.scores))
