"""Frontier-batched (beam) search cores: exactness and work-metric pins.

Three layers of evidence (DESIGN.md §6):

* ``beam_width=1`` is **bitwise identical** to the pre-beam one-pop cores —
  docs, scores, emission order, pop counts — against the verbatim anchors in
  ``tests/anchor_ranked.py``;
* ``beam_width>1`` matches the brute-force NumPy oracle on 300+ seeded
  randomized queries across AND/OR × tf-idf/BM25 × DR/DRB (the sharded
  backend is pinned by the slow subprocess test below);
* the while-loop trip count — the latency-chain work metric — drops with P
  while the pop overhead stays modest, and heap overflow is surfaced, never
  silent.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

import anchor_ranked as anchor
import oracle

from repro.core import drb, ranked, scoring
from repro.engine import EngineConfig, SearchEngine

BEAMS = (3, 8, 16)


def query_pool(idx, rng, q):
    df = np.asarray(idx.df)
    pool = np.flatnonzero((df >= 2) & (df <= int(idx.n_docs) // 2))
    return rng.choice(pool, size=q, replace=False)


# ---------------------------------------------------------------------------
# beam_width=1 == the pre-beam implementations, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("conjunctive", [True, False])
def test_beam1_is_bitwise_identical_to_onepop_dr(small_index, tfidf,
                                                 conjunctive):
    idx, _ = small_index
    idf = tfidf.idf(idx)
    cap = 2 * int(idx.n_docs) + 4
    rng = np.random.default_rng(23)
    for trial in range(4):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.asarray([True, True, trial % 2 == 0])
        for max_pops in (None, 9):
            a = anchor.topk_dr_onepop(idx, words, wmask, idf, k=10,
                                      conjunctive=conjunctive, heap_cap=cap,
                                      max_pops=max_pops)
            b = ranked.topk_dr(idx, words, wmask, idf, k=10,
                               conjunctive=conjunctive, heap_cap=cap,
                               max_pops=max_pops, beam_width=1)
            # the anchor predates the anytime harvest (DESIGN.md §11): a
            # binding budget now *additionally* fills trailing slots from
            # the pending frontier, so compare the emitted prefix — which
            # must match the anchor bitwise — and require the harvest to
            # only ever extend it
            na = int(a.n_found)
            np.testing.assert_array_equal(np.asarray(a.docs)[:na],
                                          np.asarray(b.docs)[:na])
            np.testing.assert_array_equal(np.asarray(a.scores)[:na],
                                          np.asarray(b.scores)[:na])
            assert int(b.n_found) >= na
            if max_pops is None:        # no budget: bitwise, harvest inert
                np.testing.assert_array_equal(np.asarray(a.docs),
                                              np.asarray(b.docs))
                np.testing.assert_array_equal(np.asarray(a.scores),
                                              np.asarray(b.scores))
                assert int(b.n_found) == na
            assert int(a.iters) == int(b.iters) == int(b.pops)


@pytest.mark.parametrize("measure_name", ["tfidf", "bm25"])
def test_beam1_is_bitwise_identical_to_onestep_drb(small_index, small_aux,
                                                   measure_name):
    idx, _ = small_index
    m = {"tfidf": scoring.TfIdf(), "bm25": scoring.BM25()}[measure_name]
    rng = np.random.default_rng(29)
    for trial in range(4):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.ones(3, bool)
        a = anchor.topk_drb_and_onestep(idx, small_aux, words, wmask, m, k=10)
        b = drb.topk_drb_and(idx, small_aux, words, wmask, m, k=10,
                             beam_width=1)
        np.testing.assert_array_equal(np.asarray(a.docs), np.asarray(b.docs))
        np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
        assert int(a.iters) == int(b.iters)


# ---------------------------------------------------------------------------
# beam_width>1 == brute-force oracle (the >=200-seeded-query acceptance gate)
# ---------------------------------------------------------------------------

def make_docs(rng, n_docs, max_len, vocab, min_len=3):
    return [rng.integers(1, vocab, size=int(rng.integers(min_len, max_len + 1))
                         ).astype(np.int64) for _ in range(n_docs)]


@pytest.fixture(scope="module")
def beam_engine():
    rng = np.random.default_rng(41)
    docs = make_docs(rng, 30, 20, 50)
    engine = SearchEngine.build(docs, EngineConfig(block=128), vocab_size=50)
    return rng, docs, engine


def test_beam_matches_oracle_300_cases(beam_engine):
    """DR/DRB × and/or × tfidf/bm25 at P in {3, 8}: engine == oracle."""
    rng, docs, engine = beam_engine
    B = 18
    queries = np.stack([
        np.concatenate([rng.choice(np.arange(1, 50), 1),
                        rng.integers(1, 50, 1)])
        for _ in range(B)])
    combos = [("and", "dr", "tfidf"), ("or", "dr", "tfidf"),
              ("and", "drb", "tfidf"), ("or", "drb", "tfidf"),
              ("and", "drb", "bm25"), ("or", "drb", "bm25")]
    cases = 0
    for P in (3, 8):
        for mode, strategy, measure in combos:
            res = engine.search(queries, k=len(docs), mode=mode,
                                strategy=strategy, measure=measure,
                                beam_width=P)
            assert not bool(np.any(res.diagnostics.get("overflowed", False)))
            for b in range(B):
                exp = oracle.search_oracle(docs, queries[b], mode=mode,
                                           measure=measure, strategy=strategy,
                                           vocab_size=50)
                got = dict(res.hits(b))
                assert set(got) == set(exp), (mode, strategy, measure, P,
                                              queries[b].tolist())
                for d, s in got.items():
                    np.testing.assert_allclose(s, exp[d]["score"], rtol=2e-5,
                                               atol=1e-4)
                cases += 1
    assert cases >= 200, cases


def test_beam_emission_order_descending(small_index, tfidf):
    """Emitted scores stay globally sorted for every beam width."""
    idx, _ = small_index
    idf = tfidf.idf(idx)
    cap = 2 * int(idx.n_docs) + 4
    rng = np.random.default_rng(31)
    words = jnp.asarray(query_pool(idx, rng, 2), jnp.int32)
    for P in BEAMS:
        r = ranked.topk_dr(idx, words, jnp.ones(2, bool), idf, k=15,
                           conjunctive=False, heap_cap=cap, beam_width=P)
        s = np.asarray(r.scores)[: int(r.n_found)]
        assert (np.diff(s) <= 1e-5).all(), P


def test_beam_anytime_budget_certified(small_index, tfidf):
    """max_pops with a beam: certified slots equal the exact ranking, the
    rest are bounded (DESIGN.md §11) — at every beam width."""
    idx, _ = small_index
    idf = tfidf.idf(idx)
    cap = 2 * int(idx.n_docs) + 4
    rng = np.random.default_rng(37)
    words = jnp.asarray(query_pool(idx, rng, 2), jnp.int32)
    wmask = jnp.ones(2, bool)
    full = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                          heap_cap=cap, beam_width=4)
    budget = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                            heap_cap=cap, beam_width=4,
                            max_pops=int(full.pops) // 2)
    cert = np.asarray(budget.certified)
    assert not np.any(np.diff(cert.astype(int)) > 0)      # prefix property
    nc = int(cert.sum())
    np.testing.assert_array_equal(np.asarray(budget.docs)[:nc],
                                  np.asarray(full.docs)[:nc])
    np.testing.assert_array_equal(np.asarray(budget.scores)[:nc],
                                  np.asarray(full.scores)[:nc])
    nb = int(budget.n_found)
    s = np.asarray(budget.scores)[:nb]
    assert (np.diff(s) <= 1e-6).all()                      # still best-first
    got = set(np.asarray(budget.docs)[:nb].tolist())
    bound = float(budget.bound)
    for d, sc in zip(np.asarray(full.docs), np.asarray(full.scores)):
        if d >= 0 and int(d) not in got:
            assert sc <= bound + 1e-6


# ---------------------------------------------------------------------------
# work metric: trip count drops ~P-fold, pop overhead stays modest
# ---------------------------------------------------------------------------

def test_beam_cuts_loop_trips(small_index, tfidf):
    idx, _ = small_index
    idf = tfidf.idf(idx)
    cap = 2 * int(idx.n_docs) + 4
    rng = np.random.default_rng(43)
    it1 = it16 = p1 = p16 = 0
    for _ in range(3):
        words = jnp.asarray(query_pool(idx, rng, 3), jnp.int32)
        wmask = jnp.ones(3, bool)
        r1 = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                            heap_cap=cap, beam_width=1)
        r16 = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                             heap_cap=cap, beam_width=16)
        it1 += int(r1.iters); it16 += int(r16.iters)
        p1 += int(r1.pops); p16 += int(r16.pops)
    assert it16 * 4 <= it1, (it1, it16)          # >= 4x fewer loop trips
    assert p16 <= 3 * p1, (p1, p16)              # bounded expansion overhead


# ---------------------------------------------------------------------------
# heap overflow: flagged, never silent
# ---------------------------------------------------------------------------

def test_heap_overflow_is_flagged(small_index, tfidf):
    idx, _ = small_index
    idf = tfidf.idf(idx)
    rng = np.random.default_rng(47)
    words = jnp.asarray(query_pool(idx, rng, 2), jnp.int32)
    wmask = jnp.ones(2, bool)
    ok = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                        heap_cap=2 * int(idx.n_docs) + 4, beam_width=1)
    assert not bool(ok.overflowed)
    for P in (1, 4):
        tiny = ranked.topk_dr(idx, words, wmask, idf, k=10, conjunctive=False,
                              heap_cap=3, beam_width=P)
        assert bool(tiny.overflowed), P


def test_engine_surfaces_overflow_diagnostics():
    rng = np.random.default_rng(53)
    docs = make_docs(rng, 24, 14, 40)
    engine = SearchEngine.build(docs, EngineConfig(block=128), vocab_size=40)
    res = engine.search([[3, 7]], k=5, mode="or", strategy="dr")
    d = res.diagnostics
    assert d["beam_width"] == 1
    assert not bool(np.any(d["overflowed"]))
    assert d["pops"].shape == d["work"].shape
    # deliberately tiny heap: the engine must report, not corrupt silently
    tiny = SearchEngine.build(docs, EngineConfig(block=128), vocab_size=40)
    tiny._heap_cap = 2
    res = tiny.search([[3, 7]], k=5, mode="or", strategy="dr")
    assert bool(np.any(res.diagnostics["overflowed"]))


def test_beam_executor_cache_no_retrace():
    """Same beam_width reuses the compiled executor; a new width compiles."""
    rng = np.random.default_rng(59)
    docs = make_docs(rng, 20, 12, 40)
    engine = SearchEngine.build(docs, EngineConfig(block=128), vocab_size=40)
    q = [[4, 9]]
    engine.search(q, k=5, mode="or", strategy="dr", beam_width=4)
    n_exec = engine.stats["executors"]
    traces = dict(engine.stats["traces"])
    engine.search(q, k=5, mode="or", strategy="dr", beam_width=4)
    assert engine.stats["executors"] == n_exec
    assert engine.stats["traces"] == traces
    engine.search(q, k=5, mode="or", strategy="dr", beam_width=8)
    assert engine.stats["executors"] == n_exec + 1


def test_beam_width_validation():
    rng = np.random.default_rng(61)
    docs = make_docs(rng, 10, 10, 30)
    engine = SearchEngine.build(docs, EngineConfig(block=128), vocab_size=30)
    with pytest.raises(ValueError, match="beam_width"):
        engine.search([[3]], k=3, beam_width=0)
    with pytest.raises(ValueError, match="beam_width"):
        engine.search([[3, 4]], mode="phrase", beam_width=2)
    with pytest.raises(ValueError, match="default_beam_width"):
        EngineConfig(default_beam_width=0)


# ---------------------------------------------------------------------------
# sharded backend (slow: subprocess with simulated devices)
# ---------------------------------------------------------------------------

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.engine import EngineConfig, SearchEngine
    from repro.text import corpus

    cp = corpus.make_corpus(n_docs=64, mean_doc_len=30, vocab_size=200, seed=7)
    single = SearchEngine.build(cp)
    sharded = SearchEngine.shard(cp, n_shards=4)
    df = cp.doc_freqs()
    pool = np.flatnonzero((df >= 2) & (df <= 32))
    rng = np.random.default_rng(3)
    qs = np.stack([rng.choice(pool, 2, replace=False) for _ in range(4)])
    fails = 0
    for mode, strategy, measure in (("and", "dr", "tfidf"),
                                    ("or", "dr", "tfidf"),
                                    ("and", "drb", "bm25")):
        ref = single.search(qs, k=10, mode=mode, strategy=strategy,
                            measure=measure, beam_width=1)
        for P in (1, 4):
            res = sharded.search(qs, k=10, mode=mode, strategy=strategy,
                                 measure=measure, beam_width=P)
            for b in range(len(qs)):
                a = np.sort(np.asarray(ref.scores[b]))[::-1]
                g = np.sort(np.asarray(res.scores[b]))[::-1]
                if not (np.allclose(a, g, atol=1e-4)
                        and int(ref.n_found[b]) == int(res.n_found[b])):
                    fails += 1
                    print("MISMATCH", mode, strategy, measure, P, b)
    print("FAILS", fails)
    raise SystemExit(1 if fails else 0)
""")


@pytest.mark.slow
def test_sharded_beam_matches_single(tmp_path):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
