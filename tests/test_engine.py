"""repro.engine.SearchEngine — facade contract tests.

* search agrees with the ``ranked.topk_bruteforce`` oracle across every
  (strategy, mode, measure) combination the measures permit,
* invalid routing (DR + BM25, budget + DRB, bad ids/modes) is rejected,
* the executor cache actually prevents retracing (jax.jit trace counting),
* a full facade round-trip build -> search -> snippets reconstructs the
  indexed text.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ranked, scoring
from repro.engine import EngineConfig, SearchEngine


# engine_corpus / engine / query_batch fixtures are session-scoped in
# conftest.py — shared with the differential suite.


def _bruteforce(engine, measure, word_ids, k, conjunctive):
    """Oracle ranking on raw tf (tf-idf weighting) for one query row."""
    words = jnp.asarray(engine.model.rank_of_word[word_ids], jnp.int32)
    wmask = jnp.ones(len(word_ids), bool)
    idf = measure.idf(engine.idx)
    return ranked.topk_bruteforce(engine.idx, words, wmask, idf, k=k,
                                  conjunctive=conjunctive)


@pytest.mark.parametrize("strategy", ["dr", "drb", "auto"])
@pytest.mark.parametrize("mode", ["and", "or"])
def test_search_matches_bruteforce_tfidf(engine, query_batch, strategy, mode):
    res = engine.search(query_batch, k=10, mode=mode, strategy=strategy,
                        measure="tfidf")
    assert res.strategy == ("dr" if strategy == "auto" else strategy)
    for b in range(len(query_batch)):
        bf = _bruteforce(engine, scoring.TfIdf(), query_batch[b], 10,
                         conjunctive=(mode == "and"))
        assert int(bf.n_found) == int(res.n_found[b])
        np.testing.assert_allclose(
            np.sort(np.asarray(res.scores[b]))[::-1],
            np.sort(np.asarray(bf.scores))[::-1], atol=1e-4)


@pytest.mark.parametrize("strategy", ["drb", "auto"])
@pytest.mark.parametrize("mode", ["and", "or"])
def test_search_bm25_ranks_match_oracle(engine, query_batch, strategy, mode):
    """BM25 routes to DRB; verify against a direct dense BM25 scorer."""
    res = engine.search(query_batch, k=10, mode=mode, strategy=strategy,
                        measure="bm25")
    assert res.strategy == "drb"
    measure = scoring.BM25()
    idx = engine.idx
    idf = measure.idf(idx)
    avg_dl = float(np.asarray(idx.doc_len, np.float64).sum() / int(idx.n_docs))
    import jax

    from repro.core import wtbc
    tf_all = jax.jit(lambda ws: jax.vmap(lambda d: jax.vmap(
        lambda w: wtbc.count_doc(idx, w, d))(ws))(
            jnp.arange(int(idx.n_docs), dtype=jnp.int32)))
    for b in range(len(query_batch)):
        words = jnp.asarray(engine.model.rank_of_word[query_batch[b]], jnp.int32)
        tf = np.asarray(tf_all(words))                               # (N, Q)
        scores = np.asarray(measure.score(
            jnp.asarray(tf), jnp.where(jnp.ones(3, bool), idf[words], 0.0),
            idx.doc_len, jnp.float32(avg_dl)))
        if mode == "and":
            ok = (tf > 0).all(axis=1)
        else:
            ok = (tf > 0).any(axis=1)
        scores = np.where(ok, scores, -np.inf)
        expect = np.sort(scores)[::-1][:10]
        got = np.asarray(res.scores[b])
        np.testing.assert_allclose(np.where(np.isfinite(expect), expect, -np.inf),
                                   got, atol=1e-3)


def test_dr_rejects_bm25(engine, query_batch):
    with pytest.raises(ValueError, match="not monotone"):
        engine.search(query_batch, k=5, strategy="dr", measure="bm25")


def test_budget_on_drb(engine, query_batch):
    """DRB/AND accepts an anytime budget (all-or-nothing certification,
    DESIGN.md §11); the loop-free DRB/OR path silently normalizes it off —
    same answers as an unbudgeted run, everything certified."""
    res = engine.search(query_batch, k=5, strategy="drb", budget=10)
    assert res.sla == "bounded" and res.certified is not None
    ro = engine.search(query_batch, k=5, strategy="drb", mode="or", budget=10)
    r2 = engine.search(query_batch, k=5, strategy="drb", mode="or")
    np.testing.assert_array_equal(np.asarray(ro.docs), np.asarray(r2.docs))
    assert bool(np.all(np.asarray(ro.certified)
                       == (np.asarray(ro.scores) > -np.inf)))


def test_input_validation(engine, query_batch):
    with pytest.raises(ValueError, match="mode"):
        engine.search(query_batch, mode="xor")
    with pytest.raises(ValueError, match="strategy"):
        engine.search(query_batch, strategy="fancy")
    with pytest.raises(ValueError, match="measure"):
        engine.search(query_batch, measure="pagerank")
    with pytest.raises(ValueError, match="word ids"):
        engine.search(np.zeros((2, 2), np.int64), k=3)   # id 0 is reserved
    with pytest.raises(ValueError, match="k must be positive"):
        engine.search(query_batch, k=0)


def test_ragged_and_single_queries(engine, query_batch):
    w0, w1 = int(query_batch[0, 0]), int(query_batch[0, 1])
    single = engine.search([w0], k=5, mode="or")
    assert len(single) == 1
    ragged = engine.search([[w0], [w0, w1]], k=5, mode="or")
    assert len(ragged) == 2
    # the padded row must score identically to the flat single query
    np.testing.assert_allclose(np.asarray(single.scores[0]),
                               np.asarray(ragged.scores[0]), atol=1e-6)


def test_executor_cache_no_retrace(engine_corpus, query_batch):
    engine = SearchEngine.build(engine_corpus, EngineConfig(block=512))
    engine.search(query_batch, k=5, mode="or", strategy="dr")
    traces_after_first = dict(engine.stats["traces"])
    assert sum(traces_after_first.values()) == 1
    # same (strategy, mode, measure, k, batch shape) -> cache hit, no retrace
    engine.search(query_batch, k=5, mode="or", strategy="dr")
    assert engine.stats["traces"] == traces_after_first
    assert engine.stats["executors"] == 1
    # different k -> new executor, exactly one new trace
    engine.search(query_batch, k=7, mode="or", strategy="dr")
    assert engine.stats["executors"] == 2
    assert sum(engine.stats["traces"].values()) == 2
    # different batch shape -> new executor too
    engine.search(query_batch[:1], k=5, mode="or", strategy="dr")
    assert engine.stats["executors"] == 3
    assert sum(engine.stats["traces"].values()) == 3


def test_executor_cache_retrace_regression(engine_corpus, query_batch):
    """Same (strategy, mode, measure, k, batch_shape, budget) traffic must
    hit the compiled executor — one trace per distinct key, ever."""
    engine = SearchEngine.build(engine_corpus, EngineConfig(block=512))
    for _ in range(3):
        engine.search(query_batch, k=5, mode="and", strategy="dr")
        engine.search(query_batch, k=5, mode="and", strategy="drb")
        engine.search(query_batch, k=5, mode="or", strategy="drb",
                      measure="bm25")
        engine.search(query_batch, k=5, mode="or", strategy="dr", budget=16)
    assert engine.stats["executors"] == 4
    assert all(n == 1 for n in engine.stats["traces"].values())
    # distinct budget -> distinct key, one more trace
    engine.search(query_batch, k=5, mode="or", strategy="dr", budget=32)
    assert engine.stats["executors"] == 5
    assert all(n == 1 for n in engine.stats["traces"].values())


def test_mixed_q_traffic_shares_bucketed_executor(engine_corpus, query_batch):
    """Q is padded to power-of-two buckets: batches whose longest query
    differs only within a bucket must hit ONE compiled executor (the serving
    batcher coalesces mixed-length traffic relying on this)."""
    engine = SearchEngine.build(engine_corpus, EngineConfig(block=512))
    w = [int(x) for x in query_batch.reshape(-1)[:8]]
    engine.search([w[:3]], k=5, mode="or", strategy="dr")      # Q=3 -> 4
    engine.search([w[:4]], k=5, mode="or", strategy="dr")      # Q=4 -> 4
    assert engine.stats["executors"] == 1
    # ragged batch: longest row 3 -> same Q bucket, same B -> same executor
    engine.search([[w[0], w[1], w[2]]], k=5, mode="or", strategy="dr")
    assert engine.stats["executors"] == 1
    assert all(n == 1 for n in engine.stats["traces"].values())
    # bucket boundary crossed -> one (and only one) new executor
    engine.search([w[:5]], k=5, mode="or", strategy="dr")      # Q=5 -> 8
    assert engine.stats["executors"] == 2
    # padded columns are masked out, never scored: Q=3 and Q=4-padded agree
    r3 = engine.search([w[:3]], k=5, mode="or", strategy="dr")
    r3b = engine.search([w[:3] + [w[0]]], k=5, mode="or", strategy="dr")
    assert np.asarray(r3.scores).shape == np.asarray(r3b.scores).shape


def test_warmup_precompiles_all_buckets(engine_corpus, query_batch):
    """After warmup(max_batch=4), traffic at any B <= 4 and any warmed Q
    bucket runs with ZERO new traces — the serving no-compile guarantee."""
    engine = SearchEngine.build(engine_corpus, EngineConfig(block=512))
    w = [int(x) for x in query_batch.reshape(-1)[:6]]
    examples = [w[:2], w[:3]]                  # Q buckets {2, 4}
    n = engine.warmup(examples, max_batch=4, k=5, mode="or", strategy="dr")
    assert n == engine.stats["executors"] == 6          # 2 Q x 3 B buckets
    before = dict(engine.stats["traces"])
    # B stays on warmed pow2 buckets (the serving batcher pads B to those);
    # Q mixes freely within warmed buckets
    for batch in ([w[:2]], [w[:3]] * 2, [w[:2], w[:3]], [w[:2]] * 4,
                  [w[:4], w[:3], w[:2], w[:4]]):
        engine.search(batch, k=5, mode="or", strategy="dr")
    assert engine.stats["traces"] == before


def test_df_cap_pinning(engine, query_batch):
    """An explicit df_cap keys one executor for mixed DRB/OR traffic, and a
    cap too small for a batch is rejected instead of truncating the gather."""
    cap = engine.suggested_df_cap(query_batch)
    r_auto = engine.search(query_batch, k=10, mode="or", strategy="drb",
                           measure="bm25")
    r_pin = engine.search(query_batch, k=10, mode="or", strategy="drb",
                          measure="bm25", df_cap=cap)
    np.testing.assert_array_equal(np.asarray(r_auto.docs),
                                  np.asarray(r_pin.docs))
    np.testing.assert_array_equal(np.asarray(r_auto.scores),
                                  np.asarray(r_pin.scores))
    with pytest.raises(ValueError, match="truncate"):
        engine.search(query_batch, k=10, mode="or", strategy="drb",
                      measure="bm25", df_cap=1)
    with pytest.raises(ValueError, match="df_cap"):
        engine.search(query_batch, k=10, mode="or", strategy="dr",
                      df_cap=cap)


def test_positional_modes_distinct_executor_keys(engine_corpus, query_batch):
    """phrase vs near get distinct executors; the proximity window is traced
    (changing it must NOT retrace or add executors)."""
    engine = SearchEngine.build(engine_corpus, EngineConfig(block=512))
    engine.search(query_batch, k=5, mode="phrase")
    assert engine.stats["executors"] == 1
    engine.search(query_batch, k=5, mode="near", window=4)
    assert engine.stats["executors"] == 2
    keys = list(engine.stats["traces"])
    assert {k.mode for k in keys} == {"phrase", "near"}
    # repeat traffic + a different window: cache hits only
    engine.search(query_batch, k=5, mode="phrase")
    engine.search(query_batch, k=5, mode="near", window=9)
    assert engine.stats["executors"] == 2
    assert all(n == 1 for n in engine.stats["traces"].values())
    # positional and conjunctive "dr" traffic never share an executor
    engine.search(query_batch, k=5, mode="and", strategy="dr")
    assert engine.stats["executors"] == 3


def test_positional_validation(engine, query_batch):
    with pytest.raises(ValueError, match="window"):
        engine.search(query_batch, k=5, mode="and", window=4)
    with pytest.raises(ValueError, match="window"):
        engine.search(query_batch, k=5, mode="phrase", window=4)
    with pytest.raises(ValueError, match="window must be"):
        engine.search(query_batch, k=5, mode="near", window=0)
    with pytest.raises(ValueError, match="bare WTBC"):
        engine.search(query_batch, k=5, mode="phrase", strategy="drb")
    with pytest.raises(ValueError, match="budget"):
        engine.search(query_batch, k=5, mode="near", budget=10)
    # non-positional results carry no match payloads
    res = engine.search(query_batch, k=5, mode="or")
    with pytest.raises(ValueError, match="match positions"):
        res.matches(0)


def test_round_trip_build_search_snippets():
    """Facade round-trip on a known tiny corpus: the top hit is the right
    document and its snippet decodes back to the document's own tokens."""
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 30, size=rng.integers(5, 15)).astype(np.int64)
            for _ in range(12)]
    target_word = 31
    docs[7] = np.concatenate([np.full(6, target_word, np.int64), docs[7]])
    engine = SearchEngine.build(docs, vocab_size=40)
    res = engine.search([[target_word]], k=3, mode="and")
    hits = res.hits(0)
    assert hits and hits[0][0] == 7
    snippet = engine.snippets(res, length=6)[0][0]
    np.testing.assert_array_equal(snippet, docs[7][:6])
    # brute-force agreement on the same round-trip
    bf = _bruteforce(engine, scoring.TfIdf(), [target_word], 3, conjunctive=True)
    np.testing.assert_allclose(np.asarray(res.scores[0]),
                               np.asarray(bf.scores), atol=1e-5)


def test_with_drb_false_blocks_drb():
    docs = [np.arange(1, 8, dtype=np.int64) for _ in range(4)]
    engine = SearchEngine.build(docs, EngineConfig(with_drb=False),
                                vocab_size=16)
    with pytest.raises(ValueError, match="with_drb"):
        engine.search([[2, 3]], k=2, strategy="drb")
    # DR still works
    res = engine.search([[2, 3]], k=2, strategy="auto")
    assert res.strategy == "dr"


@pytest.mark.slow
def test_sharded_facade_matches_single():
    """SearchEngine.shard == SearchEngine.build rankings (subprocess: needs
    simulated devices, and XLA's device count is locked at first jax init)."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.engine import SearchEngine
        from repro.text import corpus

        cp = corpus.make_corpus(n_docs=48, mean_doc_len=30, vocab_size=200, seed=6)
        single = SearchEngine.build(cp)
        sharded = SearchEngine.shard(cp, n_shards=4)
        df = cp.doc_freqs()
        pool = np.flatnonzero((df >= 2) & (df <= 30))
        rng = np.random.default_rng(3)
        qs = np.stack([rng.choice(pool, 2, replace=False) for _ in range(3)])
        fails = 0
        combos = [("and", "dr", "tfidf"), ("or", "dr", "tfidf"),
                  ("and", "drb", "tfidf"), ("or", "drb", "tfidf"),
                  ("and", "drb", "bm25"), ("or", "drb", "bm25")]
        for mode, strategy, measure in combos:
            a = single.search(qs, k=8, mode=mode, strategy=strategy,
                              measure=measure)
            b = sharded.search(qs, k=8, mode=mode, strategy=strategy,
                               measure=measure)
            for q in range(3):
                if int(a.n_found[q]) != int(b.n_found[q]) or not np.allclose(
                        np.sort(np.asarray(a.scores[q])),
                        np.sort(np.asarray(b.scores[q])), atol=1e-4):
                    fails += 1
                    print("MISMATCH", mode, strategy, measure, q)
        sn = sharded.snippets(sharded.search(qs, k=3, mode="or"), length=4)
        assert len(sn) == 3
        print("FAILS", fails)
        raise SystemExit(1 if fails else 0)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


def test_dr_budget_is_anytime_prefix(engine, query_batch):
    """A budgeted DR search returns a prefix of the exact ranking."""
    exact = engine.search(query_batch[:1], k=10, mode="or", strategy="dr")
    budgeted = engine.search(query_batch[:1], k=10, mode="or", strategy="dr",
                             budget=5)
    n = int(budgeted.n_found[0])
    assert int(budgeted.work[0]) <= 5
    np.testing.assert_allclose(np.asarray(budgeted.scores[0])[:n],
                               np.asarray(exact.scores[0])[:n], atol=1e-5)
