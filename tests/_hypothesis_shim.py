"""Guarded hypothesis import for the property-based tests.

``hypothesis`` is a dev extra (``pip install -e .[dev]``, see pyproject.toml),
not a runtime dependency.  Importing it unconditionally made the whole tier-1
suite fail at *collection* on minimal installs; a module-level
``pytest.importorskip`` would instead skip every deterministic test sharing
the module.  This shim keeps both populations healthy:

* hypothesis installed  -> re-export the real ``given`` / ``settings`` /
  ``strategies`` and all property tests run as written;
* hypothesis missing    -> ``given`` rewrites each property test into a
  zero-argument test whose body is ``pytest.importorskip("hypothesis")``,
  so exactly the property tests report SKIPPED and everything else runs.

Usage in a test module::

    from _hypothesis_shim import given, settings, st
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped_property_test():
                pytest.importorskip("hypothesis")
            _skipped_property_test.__name__ = fn.__name__
            _skipped_property_test.__doc__ = fn.__doc__
            return _skipped_property_test
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _NullStrategies:
        """Placeholder so strategy expressions in decorators still evaluate."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()
