"""Differential anchors: the pre-beam one-pop search cores, verbatim.

These are the classical (beam_width-less) implementations of Algorithm 1 and
the DRB triplet walk exactly as they shipped before frontier batching; the
beam rewrite at ``beam_width=1`` must reproduce their output *exactly* —
docs, scores, emission order, pop counts (tests/test_beam.py).  They live in
the test tree on purpose: they are specification pins, not product code.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import heap as H
from repro.core import wtbc
from repro.core.drb import INT32_MAX, word_rank1
from repro.core.ranked import DRResult, count_words_range


@functools.partial(jax.jit,
                   static_argnames=("k", "conjunctive", "heap_cap", "max_pops"))
def topk_dr_onepop(idx, words, wmask, idf, *, k: int, conjunctive: bool,
                   heap_cap: int, max_pops: int | None = None) -> DRResult:
    """The original one-pop-per-iteration Algorithm 1 (pre-beam)."""
    Q = words.shape[0]
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)

    def seg_score(tf):
        return jnp.dot(tf.astype(jnp.float32), idf_w)

    def seg_valid(tf, score):
        if conjunctive:
            return jnp.all((tf > 0) | ~wmask) & jnp.any(wmask)
        return score > 0.0

    n_docs = idx.n_docs
    lo0, hi0 = wtbc.segment_extent(idx, jnp.int32(0), n_docs)
    tf0 = count_words_range(idx, words, lo0, hi0) * wmask
    score0 = seg_score(tf0)
    pay0 = jnp.concatenate([jnp.stack([jnp.int32(0), n_docs]), tf0])
    hp = H.make(heap_cap, 2 + Q)
    hp = H.push(hp, score0, pay0, seg_valid(tf0, score0))

    out_docs = jnp.full((k,), -1, jnp.int32)
    out_scores = jnp.full((k,), -jnp.inf, jnp.float32)

    def cond(st):
        hp, _, _, n_out, it = st
        ok = (n_out < k) & (hp.size > 0)
        if max_pops is not None:
            ok = ok & (it < max_pops)
        return ok

    def body(st):
        hp, out_docs, out_scores, n_out, it = st
        score, pay, hp = H.pop(hp)
        d0, d1 = pay[0], pay[1]
        tf = pay[2:]
        single = (d1 - d0) == 1

        at = jnp.where(single, n_out, jnp.int32(0))
        out_docs = out_docs.at[at].set(jnp.where(single, d0, out_docs[at]))
        out_scores = out_scores.at[at].set(jnp.where(single, score, out_scores[at]))
        n_out = n_out + single.astype(jnp.int32)

        mid = (d0 + d1) // 2
        lo1, hi1 = wtbc.segment_extent(idx, d0, mid)
        tf1 = count_words_range(idx, words, lo1, hi1) * wmask
        tf2 = tf - tf1
        s1, s2 = seg_score(tf1), seg_score(tf2)
        pay1 = jnp.concatenate([jnp.stack([d0, mid]), tf1])
        pay2 = jnp.concatenate([jnp.stack([mid, d1]), tf2])
        hp = H.push(hp, s1, pay1, ~single & seg_valid(tf1, s1))
        hp = H.push(hp, s2, pay2, ~single & seg_valid(tf2, s2))
        return hp, out_docs, out_scores, n_out, it + 1

    hp, out_docs, out_scores, n_out, iters = jax.lax.while_loop(
        cond, body, (hp, out_docs, out_scores, jnp.int32(0), jnp.int32(0)))
    return DRResult(out_docs, out_scores, n_out, iters)


@functools.partial(jax.jit, static_argnames=("k", "measure"))
def topk_drb_and_onestep(idx, aux, words, wmask, measure, *, k: int,
                         idf=None, avg_dl=None) -> DRResult:
    """The original one-candidate-per-iteration DRB triplet walk (pre-beam)."""
    Q = words.shape[0]
    valid = wmask & aux.has_bm[words]
    idf_all = measure.idf(idx) if idf is None else idf
    idf_w = jnp.where(valid, idf_all[words], 0.0).astype(jnp.float32)
    df_w = idx.df[words]
    if avg_dl is None:
        avg_dl = jnp.sum(idx.doc_len.astype(jnp.float32)) / idx.n_docs.astype(jnp.float32)
    absent = jnp.any(wmask & (df_w == 0))

    p0 = jnp.zeros((Q,), jnp.int32)
    nd0 = jnp.where(valid, df_w, INT32_MAX)
    topk0 = H.topk_make(k)

    def cond(st):
        p, nd, topk, it = st
        return (jnp.min(nd) > 0) & jnp.any(valid) & ~absent & (it < idx.n_docs + 1)

    def body(st):
        p, nd, topk, it = st
        qstar = jnp.argmin(jnp.where(valid, nd, INT32_MAX))
        wstar = words[qstar]
        pos = wtbc.locate(idx, wstar, p[qstar] + 1)
        d = wtbc.doc_of_pos(idx, pos)
        lo, hi = wtbc.segment_extent(idx, d, d + 1)
        cnt_hi = count_words_range(idx, words, jnp.int32(0), hi)
        cnt_lo = count_words_range(idx, words, jnp.int32(0), lo)
        tf = (cnt_hi - cnt_lo) * valid
        present = jnp.all((tf > 0) | ~valid) & jnp.any(valid)
        score = measure.score(tf, idf_w, idx.doc_len[d], avg_dl)
        topk = H.topk_insert(topk, score, d, present)
        p_new = jnp.where(valid, cnt_hi, p)
        nd_new = jax.vmap(lambda w_, c_: word_rank1(aux, w_, c_))(words, cnt_hi)
        nd_new = jnp.where(valid, df_w - nd_new, INT32_MAX)
        return p_new, nd_new, topk, it + 1

    p, nd, topk, iters = jax.lax.while_loop(cond, body, (p0, nd0, topk0, jnp.int32(0)))
    res = H.topk_sorted(topk)
    found = jnp.sum(res.scores > -jnp.inf).astype(jnp.int32)
    return DRResult(jnp.where(res.scores > -jnp.inf, res.docs, -1),
                    res.scores, found, iters)
