"""Fault-injection units (DESIGN.md §11) — the fast, deterministic slices.

The heavyweight end-to-end suite lives in ``repro.serve.faults`` (run by the
CI ``anytime-smoke`` job as ``python -m repro.serve.faults``); these tests
pin the individual mechanisms it composes: ticket finalization races, the
retry/backoff policy, the admission degradation ladder, and cache-poison
unreachability — each small enough for tier-1.
"""
import queue

import numpy as np
import pytest

from repro.serve.batcher import QueryProfile
from repro.serve.faults import (POISON_DOC, FaultPlan, FaultyEngine,
                                InjectedDispatchError, poison_cache)
from repro.serve.loadgen import (LoadReport, RetryPolicy, closed_loop,
                                 sample_queries)
from repro.serve.server import (MIN_BUDGET, RequestTimeout, RowResult,
                                SearchServer, ShedError, Ticket)


def _row(k=4):
    return RowResult(docs=np.zeros(k, np.int32), scores=np.zeros(k, np.float32),
                     n_found=k, work=1, k=k, mode="or", strategy="dr",
                     measure="tfidf")


# -- ticket finalization ----------------------------------------------------

def test_ticket_cancel_beats_late_complete():
    t = Ticket(np.arange(3), QueryProfile(mode="or", k=4))
    assert t.cancel(RequestTimeout("deadline")) is True
    assert t.done()
    t._complete(result=_row())          # late dispatch: must NOT resurrect
    with pytest.raises(RequestTimeout):
        t.result(0.0)
    assert t.cancel(RequestTimeout("again")) is False   # already finalized


def test_ticket_complete_beats_late_cancel():
    t = Ticket(np.arange(3), QueryProfile(mode="or", k=4))
    t._complete(result=_row())
    assert t.cancel(RequestTimeout("too late")) is False
    assert t.result(0.0).n_found == 4 and t.error is None


def test_report_classifies_timeout_vs_error():
    served = Ticket(np.arange(2), QueryProfile())
    served._complete(result=_row())
    timed = Ticket(np.arange(2), QueryProfile())
    timed.cancel(RequestTimeout("gave up"))
    errored = Ticket(np.arange(2), QueryProfile())
    errored._complete(error=InjectedDispatchError("boom"))

    class _Stub:
        stats = {}
    rep = LoadReport.from_tickets([served, timed, errored], 0, 1.0, _Stub(),
                                  retry_hist={0: 2, 1: 1})
    assert (rep.n_ok, rep.n_timeout, rep.n_err) == (1, 1, 1)
    assert rep.n_retried == 1 and rep.retry_hist == {0: 2, 1: 1}


# -- retry policy -----------------------------------------------------------

def test_retry_backoff_bounded_jitter():
    pol = RetryPolicy(max_retries=3, base_ms=2.0, seed=7)
    rng = np.random.default_rng(7)
    for attempt in range(4):
        lo = pol.base_ms * (2 ** attempt) / 1e3
        for _ in range(16):
            b = pol.backoff_s(attempt, rng)
            assert lo <= b <= 2 * lo, (attempt, b)
    # seeded determinism: same rng seed -> same backoff sequence
    a = [pol.backoff_s(1, np.random.default_rng(3)) for _ in range(3)]
    assert a[0] == a[1] == a[2]


def test_closed_loop_retries_sheds():
    """A server that sheds each query once then serves it: every request
    must land on attempt 1 (retry_hist {1: n}), none shed in the report."""
    class FlakyServer:
        stats = {}

        def __init__(self):
            self.seen = set()

        def submit(self, words, profile):
            key = int(np.asarray(words)[0])
            if key not in self.seen:
                self.seen.add(key)
                raise ShedError("transient overload")
            t = Ticket(words, profile)
            t._complete(result=_row())
            return t

    workload = [np.array([i, i + 1, i + 2]) for i in range(6)]
    rep = closed_loop(FlakyServer(), workload, n_workers=2, timeout_s=5.0,
                      retry=RetryPolicy(max_retries=2, base_ms=0.1, seed=0))
    assert rep.n_shed == 0 and rep.n_ok == 6
    assert rep.retry_hist == {1: 6} and rep.n_retried == 6


def test_closed_loop_exhausted_retries_count_as_shed():
    class AlwaysShed:
        stats = {}

        def submit(self, words, profile):
            raise ShedError("full")

    rep = closed_loop(AlwaysShed(), [np.arange(3)] * 4, n_workers=2,
                      timeout_s=5.0,
                      retry=RetryPolicy(max_retries=1, base_ms=0.1, seed=0))
    assert rep.n_shed == 4 and rep.n_ok == 0 and rep.n_retried == 0


# -- admission degradation ladder -------------------------------------------

def test_effective_ladder(engine):
    srv = SearchServer(engine, max_batch=2, max_wait_ms=0.1, queue_depth=8)
    exact = QueryProfile(mode="or", k=8)
    eff, deg = srv._effective(exact, None)
    assert not deg and eff.sla in (None, "exact") and eff.budget is None

    bounded = QueryProfile(mode="or", k=8, budget=64)
    eff, deg = srv._effective(bounded, None)
    assert not deg and eff.sla == "bounded" and eff.budget == 64

    # a deadline folds into a pow-4 budget at the live us/pop estimate;
    # the effective profile carries budget only (cache/batch keys see
    # concrete executor knobs)
    db = engine.budget_for_deadline(0.4)
    eff, deg = srv._effective(QueryProfile(mode="or", k=8), 0.4)
    assert eff.deadline_ms is None and eff.sla == "bounded"
    assert eff.budget == db
    if db is not None:
        assert db & (db - 1) == 0                 # pow-4 bucketed

    # queue pressure: non-exact traffic degrades (budget shrunk 4x,
    # floored at MIN_BUDGET), exact traffic is never silently degraded
    while srv._queue.qsize() < srv._degrade_at:
        srv._queue.put_nowait(None)
    eff, deg = srv._effective(bounded, None)
    assert deg and eff.sla == "best_effort"
    assert MIN_BUDGET <= eff.budget <= 16
    assert eff.budget < 2 * engine.n_docs + 2     # actually cuts work
    eff, deg = srv._effective(QueryProfile(mode="or", k=8, sla="exact"), None)
    assert not deg and eff.sla == "exact"
    with pytest.raises(ValueError, match="exact"):
        srv._effective(QueryProfile(mode="or", k=8, sla="exact"), 5.0)
    while True:                                   # leave the queue clean
        try:
            srv._queue.get_nowait()
        except queue.Empty:
            break


def test_faulty_engine_is_seeded_and_transparent(engine):
    plan = FaultPlan(p_error=0.5, seed=3)
    a = FaultyEngine(engine, plan)
    b = FaultyEngine(engine, plan)
    assert a.n_docs == engine.n_docs          # delegation
    q = np.asarray(sample_queries(engine, 1, seed=0)[0])[None]
    outcomes = []
    for eng in (a, b):
        got = []
        for _ in range(6):
            try:
                eng.search(np.asarray(q), k=4, mode="or")
                got.append("ok")
            except InjectedDispatchError:
                got.append("err")
        outcomes.append(got)
    assert outcomes[0] == outcomes[1]         # same seed, same fault trace
    assert "err" in outcomes[0] and "ok" in outcomes[0]
    assert a.n_injected_errors == b.n_injected_errors > 0


# -- cache poisoning --------------------------------------------------------

def test_poisoned_cache_entry_never_served(engine):
    profile = QueryProfile(mode="or", k=6)
    q = sample_queries(engine, 1, seed=1)[0]
    with SearchServer(engine, max_batch=2, max_wait_ms=0.1,
                      queue_depth=8) as srv:
        fake = poison_cache(srv, q, profile)
        assert int(fake.docs[0]) == POISON_DOC
        row = srv.search(q, profile, timeout=60.0)
        assert row.n_found == 0 or int(row.docs[0]) != POISON_DOC
        # the genuine answer is cached under the live tag; still clean
        row2 = srv.search(q, profile, timeout=60.0)
        assert int(row2.docs[0]) == int(row.docs[0])
