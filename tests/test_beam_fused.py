"""Device-resident beam iteration (ISSUE 8, DESIGN.md §9) — parity + wiring.

Four layers, mirroring the PR's pieces:

* **backend resolution** — the interpret-only-when-asked contract of
  ``kernels/backend.py``: auto-detection per host platform, the explicit >
  force > env > auto precedence, forced-accelerator-on-CPU degrading to the
  interpreter (how CI exercises the Triton path), and the regression that a
  kernel entry point called WITHOUT an interpret flag resolves it from the
  host instead of silently interpreting;
* **fused beam step** — a 210-case randomized A/B sweep (the test_mega case
  generator, on the DR slice where ``mega=True`` engages) pinning the fused
  single-launch beam iteration (``kernels/beam_step.py``, selected via
  ``force_plan("gpu:interpret")``) BITWISE against the jnp pool path —
  results *and* loop counters — plus the empty-range / conjunctive-miss and
  pool-overflow-latch edges.  The shared engine corpus spans ~9 counter
  blocks, so descents cross block boundaries throughout;
* **engine threading** — ``EngineConfig.kernel_backend`` routing, the
  ``ExecutorKey.lowering`` cache split (a forced plan never reuses a program
  compiled under another lowering), and config validation;
* **active-frontier buckets** — ``topk_dr_batch``'s scalar-dispatch bucketed
  loop is bitwise ``vmap(topk_dr)`` on every leaf at every width, P=1 never
  pads, and pad waste is surfaced through ``SearchResults.diagnostics``;
  plus the arithmetic of the WTBC query-path roofline model these counters
  feed (``analysis/roofline.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_mega import _sweep_cases

from repro.analysis import roofline
from repro.core import ranked
from repro.engine import EngineConfig, SearchEngine
from repro.kernels import backend, ops, ref
from repro.text import corpus


# ---------------------------------------------------------------------------
# backend resolution (the interpret-default fix)
# ---------------------------------------------------------------------------

def test_resolve_interpret_auto_detection(monkeypatch):
    """Explicit flags win; None resolves from the host platform — on an
    accelerator the kernel must COMPILE, never silently interpret."""
    assert backend.resolve_interpret(True) is True
    assert backend.resolve_interpret(False) is False
    assert backend.resolve_interpret(None) == (
        backend.canonical_backend() not in backend.ACCELERATORS)
    for platform, want in [("tpu", False), ("cuda", False), ("rocm", False),
                           ("cpu", True), ("METAL", True)]:
        monkeypatch.setattr(jax, "default_backend", lambda p=platform: p)
        assert backend.resolve_interpret(None) is want, platform
    monkeypatch.setattr(jax, "default_backend", lambda: "cuda")
    assert backend.canonical_backend() == "gpu"
    assert backend.accelerator() == "gpu"


def test_descent_plan_precedence(monkeypatch):
    auto = backend.descent_plan().tag
    assert auto in ("ref", "tpu", "gpu")
    monkeypatch.setenv(backend.ENV_VAR, "gpu:interpret")
    assert backend.descent_plan().tag == "gpu:interpret"      # env > auto
    with backend.force_plan("ref"):
        assert backend.descent_plan().tag == "ref"            # force > env
        assert backend.descent_plan("tpu:interpret").tag == "tpu:interpret"
    assert backend.descent_plan().tag == "gpu:interpret"      # force restored
    monkeypatch.delenv(backend.ENV_VAR)
    assert backend.descent_plan().tag == auto
    with pytest.raises(ValueError):
        backend.descent_plan("metal")
    with pytest.raises(ValueError):
        with backend.force_plan("bogus"):
            pass                                              # pragma: no cover


def test_forced_accelerator_degrades_to_interpret():
    """Forcing a lowering the host cannot compile runs its body under the
    Pallas interpreter — the CI gpu-lowering configuration."""
    if backend.accelerator():
        pytest.skip("host has a real accelerator")
    assert backend.descent_plan("gpu") == backend.KernelPlan("gpu", True)
    assert backend.descent_plan("tpu") == backend.KernelPlan("tpu", True)
    assert backend.descent_plan("auto").tag == "ref"
    # direct kernel calls cannot fall back to jnp: ref -> portable interpret
    assert backend.kernel_plan("ref").tag == "gpu:interpret"
    assert backend.kernel_plan(None).interpret is True
    assert backend.kernel_plan("gpu", interpret=False).interpret is False


def test_kernel_entry_interpret_defaults(small_index):
    """Regression (the old ``interpret=True`` defaults): entry points called
    with NO interpret flag resolve it from the host and still match the
    oracle — on this CPU host that means the interpreter, chosen by policy
    rather than by a hard-coded default."""
    from repro.core import bytemap
    from repro.kernels import byte_rank as brk
    from repro.kernels import wavelet_descent as wd

    idx, _ = small_index
    rng = np.random.default_rng(11)
    words = jnp.asarray(rng.integers(1, idx.vocab_size, 8), jnp.int32)
    lo = jnp.zeros(8, jnp.int32)
    hi = jnp.asarray(rng.integers(0, int(idx.n) + 1, 8), jnp.int32)
    got = wd.wavelet_descent(idx.levels, idx.cw, idx.cw_len, idx.node_off,
                             idx.base_rank, words, lo, hi,
                             block=idx.levels[0].block)   # no interpret arg
    want = ref.wavelet_count_ref(idx.levels, idx.cw, idx.cw_len,
                                 idx.node_off, idx.base_rank, words, lo, hi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    data = rng.integers(0, 16, 3000).astype(np.uint8)
    bm = bytemap.build(data, block=512)
    bq = jnp.asarray(rng.integers(0, 16, 6), jnp.int32)
    pq = jnp.asarray(rng.integers(0, 3001, 6), jnp.int32)
    got = brk.byte_rank(bm.data, bm.counts, bm.length, bq, pq, block=512)
    want = ref.byte_rank_ref(bm.data, bm.counts, bm.length, bq, pq, block=512)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# fused beam step vs the jnp pool path — 210-case randomized A/B
# ---------------------------------------------------------------------------

FUSED_MODES = ("and", "or")
FUSED_CASES_PER_MODE = 105          # 2 x 105 = 210 (ISSUE floor: 210)
MEGA_KW = dict(strategy="dr", measure="tfidf", k=8, mega=True)


def test_fused_sweep_meets_case_floor():
    assert len(FUSED_MODES) * FUSED_CASES_PER_MODE >= 210


def _assert_same_result(a, b, msg=""):
    for name in ("docs", "scores", "n_found", "work", "pops", "overflowed"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=f"{name} {msg}")


@pytest.mark.parametrize("mode", FUSED_MODES)
def test_fused_beam_step_sweep_bitwise(engine, engine_corpus, mode):
    """The fused single-launch beam iteration equals the jnp pool path
    bitwise — results AND loop counters — at matched (P, Q, cap) across a
    seeded randomized sweep."""
    cases = _sweep_cases(engine_corpus, 800 + FUSED_MODES.index(mode),
                         FUSED_CASES_PER_MODE)
    for case in cases:
        plain = engine.search(case, mode=mode, **MEGA_KW)
        with backend.force_plan("gpu:interpret"):
            fused = engine.search(case, mode=mode, **MEGA_KW)
        _assert_same_result(plain, fused, f"mode={mode} case={case}")


def test_fused_empty_range_and_conjunctive_miss(engine, engine_corpus):
    """Edge rows: rare-word AND queries that intersect to nothing (empty
    ranges popped, n_found = 0) and a row mixing hit + miss words."""
    df = engine_corpus.doc_freqs()
    ids = np.arange(1, len(df))                   # id 0 is the separator
    rare = [int(w) for w in ids[df[ids] == 1][:3]]
    commons = [int(w) for w in ids[np.argsort(-df[ids])][:2]]
    assert len(rare) == 3
    case = [rare, commons + rare[:1], rare[:1] + commons]
    plain = engine.search(case, mode="and", **MEGA_KW)
    with backend.force_plan("gpu:interpret"):
        fused = engine.search(case, mode="and", **MEGA_KW)
    _assert_same_result(plain, fused, "edge rows")


def test_fused_overflow_latch_bitwise():
    """An undersized pool drops inserts and latches per-row ``overflowed``
    identically on both paths — never corrupts silently."""
    cp = corpus.make_corpus(n_docs=12, mean_doc_len=20, vocab_size=60, seed=2)
    eng = SearchEngine.build(cp, EngineConfig(block=512))
    eng._mega_cap = 2             # root fills slot 0: first split overflows
    df = cp.doc_freqs()
    pool = np.flatnonzero(df >= 4)
    q = list(map(int, pool[pool >= 1][:3]))
    plain = eng.search([q], mode="or", strategy="dr", k=5, mega=True)
    assert np.asarray(plain.overflowed).any()
    with backend.force_plan("gpu:interpret"):
        fused = eng.search([q], mode="or", strategy="dr", k=5, mega=True)
    _assert_same_result(plain, fused, "overflow latch")


# ---------------------------------------------------------------------------
# engine threading: config knob, executor-cache lowering split
# ---------------------------------------------------------------------------

def test_engine_kernel_backend_config_routes_fused(engine_corpus, engine,
                                                   query_batch):
    """``EngineConfig(kernel_backend=...)`` pins the lowering without any
    force/env — same answers, distinct compiled program."""
    pinned = SearchEngine.build(engine_corpus,
                                EngineConfig(block=512,
                                             kernel_backend="gpu:interpret"))
    a = engine.search(query_batch, mode="or", **MEGA_KW)
    b = pinned.search(query_batch, mode="or", **MEGA_KW)
    _assert_same_result(a, b, "config-pinned lowering")
    assert {k.lowering for k in pinned._executors} == {"gpu:interpret"}


def test_executor_cache_splits_on_lowering(engine, query_batch):
    """A forced plan compiles its own executor — ``ExecutorKey.lowering``
    keeps it from ever hitting a program cached under another lowering."""
    kw = dict(mode="and", **MEGA_KW)
    engine.search(query_batch, **kw)
    with backend.force_plan("gpu:interpret"):
        engine.search(query_batch, **kw)
    lows = {k.lowering for k in engine._executors if k.mega}
    assert "gpu:interpret" in lows and len(lows) >= 2


def test_invalid_kernel_backend_rejected():
    with pytest.raises(ValueError):
        EngineConfig(kernel_backend="cuda")


# ---------------------------------------------------------------------------
# active-frontier buckets: bitwise vs vmapped serial core, pad accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("conjunctive", [True, False])
@pytest.mark.parametrize("P", [1, 3, 16, 64])
def test_bucketed_batch_matches_vmapped_serial(small_index, tfidf, conjunctive,
                                               P):
    """The explicitly batched bucketed loop reproduces ``vmap(topk_dr)``
    bitwise on every result leaf — docs, scores, and the loop counters — at
    every width, including a one-word row and an all-masked row (live-width
    edge cases for the scalar bucket dispatch)."""
    idx, _ = small_index
    rng = np.random.default_rng(40 + P)
    B, Q = 5, 4
    words = jnp.asarray(rng.integers(1, idx.vocab_size, (B, Q)), jnp.int32)
    n_valid = np.array([Q, 1, 0, 2, 3])
    wmask = jnp.asarray(np.arange(Q)[None, :] < n_valid[:, None])
    idf = tfidf.idf(idx)
    kw = dict(k=5, conjunctive=conjunctive, heap_cap=64, max_pops=None,
              beam_width=P)
    got = ranked.topk_dr_batch(idx, words, wmask, idf, **kw)
    want = jax.vmap(lambda w, m: ranked.topk_dr(idx, w, m, idf, **kw))(
        words, wmask)
    for name in ("docs", "scores", "n_found", "iters", "pops", "overflowed"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)),
                                      err_msg=f"{name} P={P}")
    # pad waste is a property of the SCHEDULE, not the result: the batched
    # loop's bucket is the max live width across rows, so a narrow row pops
    # padded lanes the per-row adaptive bucket avoids — never fewer
    assert (np.asarray(got.padded) >= np.asarray(want.padded)).all()
    if P == 1:
        assert not np.asarray(got.padded).any()


def test_pad_waste_surfaced_in_diagnostics(engine, query_batch):
    """P=1 never pads; wider beams report per-row pad waste through
    ``SearchResults.diagnostics`` — with results invariant across widths."""
    kw = dict(mode="or", strategy="dr", measure="tfidf", k=8)
    r1 = engine.search(query_batch, beam_width=1, **kw)
    d1 = r1.diagnostics
    assert "padded" in d1 and not d1["padded"].any()
    r8 = engine.search(query_batch, beam_width=8, **kw)
    d8 = r8.diagnostics
    assert d8["padded"].shape == d8["pops"].shape
    assert (d8["padded"] >= 0).all()
    np.testing.assert_array_equal(np.asarray(r1.docs), np.asarray(r8.docs))
    np.testing.assert_array_equal(np.asarray(r1.scores), np.asarray(r8.scores))


def test_frontier_buckets_shape():
    assert ranked._frontier_buckets(1) == (1,)
    assert ranked._frontier_buckets(4) == (1, 2, 4)
    assert ranked._frontier_buckets(6) == (1, 2, 4, 6)
    assert ranked._frontier_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    idxs = [int(ranked._bucket_index(jnp.int32(n), (1, 2, 4, 6)))
            for n in (1, 2, 3, 4, 5, 6)]
    assert idxs == [0, 1, 2, 2, 3, 3]


# ---------------------------------------------------------------------------
# WTBC query-path roofline model (the numbers the counters above feed)
# ---------------------------------------------------------------------------

def test_wtbc_query_bytes_model():
    # 2 ranks x 3 levels x Q=4 x (pops+padded)=12 probes, 516 B per probe
    b = roofline.wtbc_query_bytes(pops=10, padded=2, q=4, block=512,
                                  counter_bytes=4.0)
    assert b == 2 * 3 * 4 * 12 * 516.0
    # padded lanes cost real traffic — that is the point of tracking them
    assert roofline.wtbc_query_bytes(pops=10, padded=0, q=4, block=512) < b


def test_wtbc_query_roofline_attachment():
    rl = roofline.wtbc_query_roofline(backend="cpu",
                                      measured_us_per_query=100.0,
                                      pops=10, padded=2, q=4, block=512)
    assert rl.bytes_per_query == 2 * 3 * 4 * 12 * 516.0
    np.testing.assert_allclose(
        rl.model_us_per_query,
        rl.bytes_per_query / roofline.WTBC_MEM_BW["cpu"] * 1e6)
    np.testing.assert_allclose(rl.achieved_frac,
                               rl.model_us_per_query / 100.0)
    # the TPU lowering DMAs the whole counter row next to each tile
    tpu = roofline.wtbc_query_roofline(backend="tpu",
                                       measured_us_per_query=100.0,
                                       pops=10, padded=2, q=4, block=512)
    assert tpu.bytes_per_query == 2 * 3 * 4 * 12 * (512 + 1024.0)
