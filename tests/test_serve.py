"""repro.serve — serving subsystem contract tests.

The one non-negotiable (DESIGN.md §7): nothing on the serving path may change
ranked output.  Snapshot round-trips, micro-batched dispatch, and cache hits
are all pinned BITWISE against direct ``engine.search`` — not approximately.
Plus: scheduler/no-retrace guarantees, backpressure, LRU mechanics, the
serving smoke (the CI job's contract), and the paper's index-overhead claim.
"""
import time
import types

import numpy as np
import pytest

from repro.engine import EngineConfig, SearchEngine
from repro.serve import (LRUCache, QueryProfile, SearchServer, ShedError,
                         loadgen, snapshot)
from repro.text import corpus


@pytest.fixture(scope="module")
def serve_corpus():
    return corpus.make_corpus(n_docs=100, mean_doc_len=50, vocab_size=400,
                              seed=11)


@pytest.fixture(scope="module")
def serve_engine(serve_corpus):
    return SearchEngine.build(serve_corpus, EngineConfig(block=512))


@pytest.fixture(scope="module")
def serve_queries(serve_engine):
    return loadgen.sample_queries(serve_engine, 24, 3, seed=5)


def _assert_rows_bitwise(row, direct, b=0):
    np.testing.assert_array_equal(row.docs, np.asarray(direct.docs[b]))
    np.testing.assert_array_equal(row.scores, np.asarray(direct.scores[b]))
    assert row.n_found == int(direct.n_found[b])


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------

SNAPSHOT_COMBOS = [
    dict(mode="and", strategy="dr", measure="tfidf"),
    dict(mode="or", strategy="dr", measure="tfidf"),
    dict(mode="and", strategy="drb", measure="bm25"),
    dict(mode="or", strategy="drb", measure="bm25"),
    dict(mode="phrase", strategy="auto", measure="tfidf"),
    dict(mode="near", strategy="auto", measure="tfidf", window=6),
]


def test_snapshot_roundtrip_bitwise(serve_corpus, serve_engine, serve_queries,
                                    tmp_path):
    """save -> load -> search is bitwise identical to the in-memory engine:
    docs, scores, counts, diagnostics, and positional payloads."""
    phrase_qs = corpus.sample_ngram_queries(serve_corpus.doc_tokens, 4, 3,
                                            seed=3)
    snapshot.save(serve_engine, tmp_path)
    restored = snapshot.load(tmp_path)
    assert restored.n_docs == serve_engine.n_docs
    assert restored.config == serve_engine.config
    for combo in SNAPSHOT_COMBOS:
        qs = (phrase_qs if combo["mode"] in ("phrase", "near")
              else serve_queries[:6])
        a = serve_engine.search(qs, k=8, **combo)
        b = restored.search(qs, k=8, **combo)
        for name in ("docs", "scores", "n_found", "work"):
            np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                          np.asarray(getattr(b, name)),
                                          err_msg=f"{combo} {name}")
        for name in ("pops", "overflowed", "match_pos", "match_len"):
            av, bv = getattr(a, name), getattr(b, name)
            assert (av is None) == (bv is None), f"{combo} {name}"
            if av is not None:
                np.testing.assert_array_equal(np.asarray(av), np.asarray(bv),
                                              err_msg=f"{combo} {name}")
    # decode straight from the restored compressed index
    res = restored.search(serve_queries[:1], k=3, mode="or")
    sn = restored.snippets(res, length=5)
    np.testing.assert_array_equal(
        sn[0][0], serve_engine.snippets(res, length=5)[0][0])


def test_snapshot_versioning(serve_engine, tmp_path):
    p1 = snapshot.save(serve_engine, tmp_path)
    p2 = snapshot.save(serve_engine, tmp_path)
    assert (p1.name, p2.name) == ("step_00000001", "step_00000002")
    assert snapshot.list_versions(tmp_path) == [1, 2]
    old = snapshot.load(tmp_path, version=1)
    new = snapshot.load(tmp_path)
    assert old.n_docs == new.n_docs


def test_snapshot_without_drb(tmp_path):
    docs = [np.arange(1, 9, dtype=np.int64) for _ in range(5)]
    eng = SearchEngine.build(docs, EngineConfig(with_drb=False), vocab_size=16)
    snapshot.save(eng, tmp_path)
    restored = snapshot.load(tmp_path)
    res = restored.search([[2, 3]], k=2, strategy="auto")
    assert res.strategy == "dr"
    with pytest.raises(ValueError, match="with_drb"):
        restored.search([[2, 3]], k=2, strategy="drb")


def test_snapshot_format_guard(serve_engine, tmp_path):
    from repro.checkpoint import ckpt
    snapshot.save(serve_engine, tmp_path)
    man, step = ckpt.read_manifest(tmp_path)
    man["user_meta"]["snapshot_format"] = 999
    d = tmp_path / f"step_{step:08d}"
    (d / "MANIFEST.json").write_text(__import__("json").dumps(man))
    with pytest.raises(ValueError, match="format"):
        snapshot.load(tmp_path)


@pytest.mark.slow
def test_sharded_snapshot_roundtrip():
    """Sharded engine: snapshot -> load rebuilds the mesh and matches the
    live sharded engine bitwise (subprocess: needs simulated devices)."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.engine import SearchEngine
        from repro.serve import snapshot
        from repro.text import corpus

        cp = corpus.make_corpus(n_docs=48, mean_doc_len=30, vocab_size=200,
                                seed=6)
        sharded = SearchEngine.shard(cp, n_shards=4)
        df = cp.doc_freqs()
        pool = np.flatnonzero((df >= 2) & (df <= 30))
        rng = np.random.default_rng(3)
        qs = np.stack([rng.choice(pool, 2, replace=False) for _ in range(3)])
        with tempfile.TemporaryDirectory() as d:
            snapshot.save(sharded, d)
            restored = snapshot.load(d)
            assert restored.backend == "sharded"
            for mode, strategy, measure in [("and", "dr", "tfidf"),
                                            ("or", "drb", "bm25")]:
                a = sharded.search(qs, k=8, mode=mode, strategy=strategy,
                                   measure=measure)
                b = restored.search(qs, k=8, mode=mode, strategy=strategy,
                                    measure=measure)
                assert np.array_equal(np.asarray(a.docs), np.asarray(b.docs))
                assert np.array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores)), (mode, strategy)
            sn = restored.snippets(restored.search(qs, k=2, mode="or"),
                                   length=4)
            assert len(sn) == 3
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


# ---------------------------------------------------------------------------
# LRU cache
# ---------------------------------------------------------------------------

def test_lru_eviction_order():
    c = LRUCache(2)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1                  # refreshes "a"
    c.put("c", 3)                           # evicts "b" (least recent)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.stats["hits"] == 3 and c.stats["misses"] == 1
    assert len(c) == 2


def test_lru_disabled_at_zero_capacity():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None
    assert c.stats == {"hits": 0, "misses": 1, "hit_rate": 0.0,
                       "size": 0, "capacity": 0}
    with pytest.raises(ValueError):
        LRUCache(-1)


# ---------------------------------------------------------------------------
# server: exactness, cache, scheduler, backpressure
# ---------------------------------------------------------------------------

def test_server_results_bitwise_match_direct(serve_engine, serve_queries):
    """Micro-batched concurrent traffic == direct single-query search,
    bitwise, for looped (dr/and) and gather (drb/or) profiles."""
    profiles = [
        QueryProfile(mode="and", strategy="dr", k=6),
        QueryProfile(mode="or", strategy="drb", measure="bm25", k=6,
                     df_cap=serve_engine.suggested_df_cap(serve_queries)),
    ]
    for profile in profiles:
        server = SearchServer(serve_engine, max_batch=8, max_wait_ms=5.0,
                              cache_size=0)
        server.warmup(serve_queries, profile)
        with server:
            rep = loadgen.closed_loop(server, serve_queries * 2, n_workers=8,
                                      profile=profile)
        assert rep.n_ok == len(serve_queries) * 2
        assert rep.server_stats["errors"] == 0
        # some coalescing must actually have happened under 8-way concurrency
        assert max(rep.server_stats["batch_hist"]) > 1
        with SearchServer(serve_engine, max_batch=8, cache_size=0) as server2:
            for q in serve_queries:
                row = server2.search(q, profile)
                _assert_rows_bitwise(
                    row, serve_engine.search([q], **profile.search_kwargs()))


def test_server_positional_profile(serve_corpus, serve_engine):
    """phrase/near profiles serve through the same frontend, with match
    payloads intact."""
    qs = corpus.sample_ngram_queries(serve_corpus.doc_tokens, 6, 2, seed=9)
    profile = QueryProfile(mode="phrase", k=5)
    with SearchServer(serve_engine, max_batch=4, cache_size=0) as server:
        for q in qs:
            row = server.search(list(map(int, q)), profile)
            direct = serve_engine.search([list(map(int, q))],
                                         **profile.search_kwargs())
            _assert_rows_bitwise(row, direct)
            np.testing.assert_array_equal(row.match_pos,
                                          np.asarray(direct.match_pos[0]))


def test_server_cache_replays_identical_rows(serve_engine, serve_queries):
    profile = QueryProfile(mode="and", strategy="dr", k=5)
    with SearchServer(serve_engine, max_batch=4, cache_size=64) as server:
        first = [server.search(q, profile) for q in serve_queries[:8]]
        h0 = server.cache.stats["hits"]
        again = [server.search(q, profile) for q in serve_queries[:8]]
        assert server.cache.stats["hits"] == h0 + 8
        for a, b in zip(first, again):
            np.testing.assert_array_equal(a.docs, b.docs)
            np.testing.assert_array_equal(a.scores, b.scores)
        # distinct profile -> distinct cache key, no false sharing
        other = QueryProfile(mode="or", strategy="dr", k=5)
        row = server.search(serve_queries[0], other)
        _assert_rows_bitwise(
            row, serve_engine.search([serve_queries[0]],
                                     **other.search_kwargs()))


def test_server_zero_retraces_after_warmup(serve_engine, serve_queries):
    profile = QueryProfile(mode="or", strategy="drb", measure="bm25", k=5,
                           df_cap=serve_engine.suggested_df_cap(serve_queries))
    server = SearchServer(serve_engine, max_batch=8, max_wait_ms=2.0,
                          cache_size=0)
    server.warmup(serve_queries, profile)
    before = sum(serve_engine.stats["traces"].values())
    with server:
        rep = loadgen.closed_loop(server, serve_queries * 3, n_workers=8,
                                  profile=profile)
    assert rep.n_ok == len(serve_queries) * 3
    assert sum(serve_engine.stats["traces"].values()) == before


def _dummy_engine(delay_s: float = 0.0):
    """A SearchEngine stand-in with a controllable service time — lets the
    scheduler/backpressure tests run without jit variance."""
    def search(queries, **kw):
        if delay_s:
            time.sleep(delay_s)
        B = len(queries)
        k = kw.get("k") or 3
        return types.SimpleNamespace(
            docs=np.tile(np.arange(k, dtype=np.int32), (B, 1)),
            scores=np.zeros((B, k), np.float32),
            n_found=np.full(B, k, np.int32), work=np.ones(B, np.int32),
            pops=None, overflowed=None, match_pos=None, match_len=None,
            k=k, mode=kw.get("mode", "and"), strategy="dr", measure="tfidf")
    return types.SimpleNamespace(
        search=search, model=types.SimpleNamespace(vocab_size=100),
        stats={"executors": 0, "traces": {}},
        warmup=lambda *a, **kw: 0)


def test_server_sheds_when_queue_full():
    eng = _dummy_engine(delay_s=0.05)
    with SearchServer(eng, max_batch=1, max_wait_ms=0.0, queue_depth=2,
                      cache_size=0) as server:
        tickets = []
        shed = 0
        for i in range(40):
            try:
                tickets.append(server.submit([1 + i % 9]))
            except ShedError:
                shed += 1
        assert shed > 0                       # backpressure engaged
        for t in tickets:                     # admitted work still completes
            t.result(timeout=10.0)
        assert server.stats["shed"] == shed
        assert server.stats["served"] == len(tickets)


def test_server_coalesces_burst_into_buckets():
    eng = _dummy_engine(delay_s=0.02)
    with SearchServer(eng, max_batch=4, max_wait_ms=10.0, queue_depth=64,
                      cache_size=0) as server:
        tickets = [server.submit([1, 2]) for _ in range(12)]
        for t in tickets:
            t.result(timeout=10.0)
    hist = server.stats["batch_hist"]
    assert sum(b * n for b, n in hist.items()) == 12
    assert max(hist) == 4                     # bursts fill whole batches
    assert server.stats["dispatches"] < 12    # strictly fewer calls than reqs


def test_mixed_profile_flood_keeps_backpressure_bounded():
    """Assembling one profile's batch must not drain the bounded admission
    queue into the batcher's deque without limit — under a mixed-profile
    flood the shed policy still has to engage."""
    eng = _dummy_engine(delay_s=0.02)
    depth = 8
    with SearchServer(eng, max_batch=4, max_wait_ms=50.0, queue_depth=depth,
                      cache_size=0) as server:
        pa, pb = QueryProfile(k=3), QueryProfile(k=4)
        tickets, shed = [], 0
        for i in range(200):
            try:
                tickets.append(server.submit([1 + i % 9], pa if i % 2 else pb))
            except ShedError:
                shed += 1
        assert shed > 0
        # bounded: queue (depth) + batcher deque (pending_cap == depth)
        assert len(server._batcher._pending) <= depth
        for t in tickets:
            t.result(timeout=20.0)
        assert server.stats["served"] == len(tickets)


def test_loadgen_reports_errors_not_fake_latencies():
    """A dispatch-time failure must surface as n_err — never as a served
    request with a healthy-looking latency, and never by killing a client
    thread mid-workload."""
    def boom(queries, **kw):
        raise RuntimeError("engine exploded")
    eng = _dummy_engine()
    eng.search = boom
    with SearchServer(eng, max_batch=4, cache_size=0) as server:
        rep = loadgen.closed_loop(server, [[3]] * 12, n_workers=3)
    assert rep.n_ok == 0 and rep.n_err == 12
    with SearchServer(eng, max_batch=4, cache_size=0) as server:
        rep = loadgen.open_loop(server, [[3]] * 10, target_qps=500.0,
                                timeout_s=10.0)
    assert rep.n_ok == 0 and rep.n_err == 10
    assert "err" in rep.summary()


def test_ngram_sampler_queries_actually_match(serve_engine):
    """Index-decoded n-grams must phrase-match their source document."""
    qs = loadgen.sample_ngram_queries(serve_engine, 6, 3, seed=2)
    res = serve_engine.search(qs, k=3, mode="phrase")
    assert all(int(n) > 0 for n in np.asarray(res.n_found))


def test_server_rejects_bad_requests_at_admission(serve_engine, serve_queries):
    with SearchServer(serve_engine, cache_size=0) as server:
        with pytest.raises(ValueError, match="word ids"):
            server.submit([0])                # reserved separator id
        with pytest.raises(ValueError, match="empty"):
            server.submit([])
        with pytest.raises(ValueError, match="one flat query"):
            server.submit([[1, 2], [3, 4]])   # batches are the server's job
        # a query heavier than the profile's pinned df_cap is rejected at
        # admission — it must never fail its coalesced batch-mates
        heavy = int(np.asarray(serve_engine.model.word_of_rank)[1])
        narrow = QueryProfile(mode="or", strategy="drb", measure="bm25",
                              df_cap=4)
        with pytest.raises(ValueError, match="wider profile"):
            server.submit([heavy], narrow)
    with pytest.raises(RuntimeError, match="not started"):
        SearchServer(serve_engine).submit([1])


def test_server_drains_on_stop():
    eng = _dummy_engine(delay_s=0.01)
    server = SearchServer(eng, max_batch=2, max_wait_ms=0.0, queue_depth=64,
                          cache_size=0).start()
    tickets = [server.submit([5]) for _ in range(10)]
    server.stop()                             # must flush, not drop
    assert all(t.done() for t in tickets)
    assert server.stats["served"] == 10


def test_serving_smoke_200_queries(serve_engine, serve_queries):
    """The CI smoke contract: 200 queries through the batcher at low load —
    every one answered, finite p99, zero shed, zero retraces after warmup."""
    profile = QueryProfile(mode="or", strategy="drb", measure="bm25", k=5,
                           df_cap=serve_engine.suggested_df_cap(serve_queries))
    server = SearchServer(serve_engine, max_batch=8, max_wait_ms=2.0,
                          cache_size=128)
    server.warmup(serve_queries, profile)
    before = sum(serve_engine.stats["traces"].values())
    workload = loadgen.zipf_workload(serve_queries, 200, seed=1)
    with server:
        rep = loadgen.closed_loop(server, workload, n_workers=4,
                                  profile=profile)
    assert rep.n_ok == 200
    assert rep.n_shed == 0
    assert np.isfinite(rep.p99_ms)
    assert rep.server_stats["errors"] == 0
    assert sum(serve_engine.stats["traces"].values()) == before
    assert rep.server_stats["cache"]["hits"] > 0     # Zipf repeats hit


# ---------------------------------------------------------------------------
# space report (paper's 6%-18% overhead claim)
# ---------------------------------------------------------------------------

def test_index_overhead_within_paper_band():
    """WTBC query-structure overhead vs the compressed text, at the paper's
    counter density (block=32768): rank counters + node offsets + separator
    positions must land in single-digit-to-paper territory (<= 18%).  The
    O(V) codeword/df tables are reported separately — see README (they are
    vocabulary metadata both the paper's baseline and the index share, and
    they amortize with corpus growth; on this synthetic corpus V/n is far
    larger than any real collection's)."""
    cp = corpus.make_corpus(n_docs=1200, mean_doc_len=150, vocab_size=10000,
                            seed=0)
    eng = SearchEngine.build(cp, EngineConfig(block=32768))
    rep = eng.space_report()
    text = rep["level_bytes"]
    assert text > 100_000                    # the corpus is non-trivial
    core = (rep["rank_counters"] + rep["node_offsets"]
            + rep["sep_positions"])
    ratio = core / text
    assert 0.02 < ratio < 0.18, f"core overhead {ratio:.1%} outside band"
    # and the DRB bitmaps stay "a few small bitmaps" (paper: ~+3%; bit_off
    # is O(V) vocabulary metadata, counted with the tables above)
    eng.aux
    rep = eng.space_report()
    drb_bits = rep["drb_bitmap_bits_bytes"] + rep["drb_bitmap_counters"]
    assert drb_bits / text < 0.15
