"""Data pipeline: determinism (restart skip-ahead), sampler validity."""
import numpy as np
import pytest

from repro.data import graph_sampler, pipeline
from repro.models.recsys import RecsysConfig
from repro.text import corpus, vocab


def test_lm_batch_deterministic():
    a = pipeline.lm_batch(0, 7, 4, 16, 1000)
    b = pipeline.lm_batch(0, 7, 4, 16, 1000)
    c = pipeline.lm_batch(0, 8, 4, 16, 1000)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert np.asarray(a["tokens"]).max() < 1000


def test_recsys_batch_bounds():
    cfg = RecsysConfig(name="x", interaction="dot", n_sparse=4, n_dense=3,
                       embed_dim=8, table_rows=(100, 200, 50, 1000))
    b = pipeline.recsys_batch(0, 3, 32, cfg)
    sp = np.asarray(b["sparse"])
    rows = cfg.rows()
    for f in range(4):
        assert sp[:, f].max() < rows[f]
    assert b["dense"].shape == (32, 3)


def test_graph_sampler_fanout_and_relabel():
    g = graph_sampler.CSRGraph.random(n_nodes=5000, avg_deg=12, d_feat=16,
                                      n_classes=5, seed=1)
    seeds = np.arange(64)
    sub = graph_sampler.sample_subgraph(g, seeds, fanout=(15, 10),
                                        pad_nodes=64 * 166, pad_edges=64 * 165,
                                        seed=2)
    e = sub["edges"]
    live = ~((e[:, 0] == 64 * 166 - 1) & (e[:, 1] == 64 * 166 - 1))
    n_live = int(live.sum())
    assert 0 < n_live <= 64 * (15 + 15 * 10)
    assert e.max() < 64 * 166
    assert sub["label_mask"].sum() == len(seeds)    # loss only on seeds


def test_synthetic_corpus_statistics():
    cp = corpus.make_corpus(n_docs=200, mean_doc_len=50, vocab_size=2000, seed=0)
    assert cp.n_docs == 200
    df = cp.doc_freqs()
    assert df[0] == 200                 # separator in every doc
    # Zipf skew: top-50 words cover most occurrences
    freqs = np.zeros(2000, np.int64)
    for d in cp.doc_tokens:
        freqs += np.bincount(d, minlength=2000)
    top = np.sort(freqs)[::-1]
    assert top[:50].sum() > 0.4 * freqs.sum()


def test_vocabulary_roundtrip():
    docs = [["to", "be", "or", "not", "to", "be"], ["be", "quick"]]
    v = vocab.Vocabulary.from_documents(docs)
    enc = v.encode_docs(docs)
    assert [ [v.words[i] for i in e] for e in enc ] == docs
    assert v.freqs[v.id_of("be")] == 3
    assert v.freqs[0] == 2              # one '$' per document


def test_fdoc_bands_scale():
    bands = corpus.fdoc_bands(345_778)
    assert bands["i"] == (10, 100)
    small = corpus.fdoc_bands(1000)
    assert small["i"][0] >= 2 and small["iv"][1] <= 1000
