"""Table 8 (beyond-paper): the anytime budget ladder — latency vs certainty.

The anytime core (DESIGN.md §11) turns ``max_pops`` into a contract: stop
early, return the slots you can *prove* plus a score bound on everything
else.  This table measures what that contract costs and buys:

  ladder  : direct engine calls over one selective query batch at a pow-4
            budget ladder (plus the exact run) — per-call latency, recall
            against the exact oracle, certified fraction, mean pops.  The
            certified slots are *verified* against the exact run on every
            rung (a wrong certified bit fails the bench, not just CI).
  serving : the same ladder through the full server + open-loop client at
            fixed arrival rate — p50/p99 and certified fraction per rung,
            i.e. the deployable latency-vs-certainty frontier, plus one
            deadline-driven rung exercising the us/pop estimator end to
            end (``deadline_ms`` -> pop budget at admission).

The JSON carries the raw rungs and the Pareto ``frontier`` —
(certified_fraction, p99_ms) points where certainty strictly increases and
p99 is the best achieved at that certainty, so the committed trajectory
(BENCH_PR10.json) tracks a monotone curve by construction; the raw rungs
stay alongside for noise inspection.  ``certified_monotone`` (asserted)
records that certainty never *decreases* with budget.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serve import QueryProfile, SearchServer, loadgen

N_QUERIES = 32
WORDS = 3
K = 10
BUDGETS = (16, 64, 256, 1024)      # pow-4 rungs, all binding on the default
WORKERS = 16                       # benchmark corpus (never-bind = 2N+2)


def _recall(exact, res, b: int) -> float:
    ne = int(exact.n_found[b])
    if ne == 0:
        return 1.0
    got = set(np.asarray(res.docs[b])[: int(res.n_found[b])].tolist())
    hit = sum(1 for d in np.asarray(exact.docs[b])[:ne] if int(d) in got)
    return hit / ne


def _verify_certified(exact, res) -> None:
    """Certified slots must equal the exact oracle's bitwise — the bench
    re-proves the §11 contract on the benchmark corpus at every rung."""
    cert = np.asarray(res.certified)
    for b in range(cert.shape[0]):
        assert not np.any(np.diff(cert[b].astype(int)) > 0), \
            f"certified bits not a prefix (row {b})"
        nc = int(cert[b].sum())
        if not (np.array_equal(np.asarray(res.docs[b])[:nc],
                               np.asarray(exact.docs[b])[:nc])
                and np.array_equal(np.asarray(res.scores[b])[:nc],
                                   np.asarray(exact.scores[b])[:nc])):
            raise AssertionError(f"certified slots diverge from exact "
                                 f"(row {b}, {nc} certified)")


def run(bench: common.Bench | None = None, *, n_requests: int = 192,
        print_rows=print) -> dict:
    b = bench or common.build()
    engine = b.engine
    queries = loadgen.sample_queries(engine, N_QUERIES, WORDS,
                                     df_range=(2, max(8, engine.n_docs // 50)),
                                     seed=13)
    batch = np.asarray(queries, np.int32)
    never_bind = 2 * engine.n_docs + 2
    budgets = [bg for bg in BUDGETS if bg < never_bind]
    results: dict = {"config": {"n_queries": N_QUERIES, "words": WORDS,
                                "k": K, "budgets": budgets,
                                "n_requests": n_requests,
                                "profile": "dr/or/tfidf"}}

    # -- direct-call ladder --------------------------------------------------
    exact = engine.search(batch, k=K, mode="or")
    ladder: dict = {}
    for bg in [None] + budgets:
        kw = {} if bg is None else {"budget": bg}
        res = engine.search(batch, k=K, mode="or", **kw)   # warm + verify
        if bg is not None:
            _verify_certified(exact, res)
        us = common.time_fn(lambda: engine.search(batch, k=K, mode="or",
                                                  **kw).scores) \
            * 1e6 / N_QUERIES
        cf = float(res.certified_fraction())
        ncert = float(np.asarray(res.certified).sum()) / N_QUERIES
        recall = float(np.mean([_recall(exact, res, i)
                                for i in range(N_QUERIES)]))
        pops = res.pops
        mean_pops = float(np.asarray(pops).mean()) if pops is not None \
            else float("nan")
        tag = "exact" if bg is None else f"budget{bg}"
        ladder[tag] = {"us_per_query": us, "recall": recall,
                       "certified_fraction": cf, "certified_slots": ncert,
                       "mean_pops": mean_pops}
        print_rows(common.csv_row(
            f"table8/{tag}", us,
            f"recall={recall:.3f};certified={cf:.3f};"
            f"slots={ncert:.2f};pops={mean_pops:.0f}"))
    results["ladder"] = ladder
    # monotone in the certified *count*: a bigger budget proves at least as
    # many slots.  (The fraction over found slots is NOT monotone: a tiny
    # budget returns only emitted — hence fully certified — slots, while a
    # bigger one harvest-fills extra slots it cannot always prove.)
    ncs = [ladder[f"budget{bg}"]["certified_slots"] for bg in budgets] \
        + [ladder["exact"]["certified_slots"]]
    assert all(b2 >= b1 - 1e-9 for b1, b2 in zip(ncs, ncs[1:])), \
        f"certified slot count not monotone in budget: {ncs}"
    results["certified_monotone"] = True
    results["us_per_pop"] = float(engine.us_per_pop)

    # -- served ladder: the latency-vs-certainty frontier --------------------
    serving: dict = {}
    rungs = [("exact", None), ("budget_lo", {"budget": budgets[0],
                                             "sla": "bounded"}),
             ("budget_hi", {"budget": budgets[-1], "sla": "bounded"}),
             ("deadline", None)]
    dl_ms = None
    for tag, knobs in rungs:
        if tag == "exact":
            knobs = {}
        elif tag == "deadline":
            # admission converts ms -> budget via the live us/pop estimate,
            # which the unbudgeted exact rung above just fed (the server's
            # dispatch loop calls note_cost) — the end-to-end estimator path
            dl_ms = max(0.05, engine.us_per_pop * budgets[0] / 1e3)
            knobs = {"sla": "best_effort", "deadline_ms": dl_ms}
        profile = QueryProfile(mode="or", strategy="dr", measure="tfidf",
                               k=K, **knobs)
        srv = SearchServer(engine, max_batch=8, max_wait_ms=1.0,
                           cache_size=0, queue_depth=4 * WORKERS)
        srv.warmup(queries[:8], profile)
        with srv:
            rep = loadgen.closed_loop(
                srv, [queries[i % N_QUERIES] for i in range(n_requests)],
                n_workers=WORKERS, profile=profile, timeout_s=600.0)
        assert rep.n_timeout == 0 and rep.n_err == 0, rep.summary()
        serving[tag] = {"qps": rep.qps, "p50_ms": rep.p50_ms,
                        "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
                        "certified_fraction": rep.certified_fraction,
                        "degraded": rep.n_degraded, "shed": rep.n_shed}
        if tag == "deadline":
            serving[tag]["deadline_ms"] = dl_ms
        print_rows(common.csv_row(
            f"table8/serve_{tag}", rep.mean_ms * 1e3,
            f"p99={rep.p99_ms:.2f}ms;certified={rep.certified_fraction:.3f};"
            f"degraded={rep.n_degraded}"))
    results["serving"] = serving

    # Pareto frontier over the served rungs: strictly increasing certainty,
    # best p99 at each certainty level -> monotone by construction.
    pts = sorted((v["certified_fraction"], v["p99_ms"])
                 for v in serving.values())
    frontier: list = []
    for cf, p99 in pts:
        if frontier and cf <= frontier[-1][0] + 1e-9:
            frontier[-1][1] = min(frontier[-1][1], p99)
        else:
            frontier.append([cf, p99])
    while len(frontier) >= 2 and frontier[-1][1] < frontier[-2][1]:
        frontier.pop(-2)            # dominated: more certainty, less p99
    results["frontier"] = frontier
    # proves the estimator moved off its cold-start default during serving
    results["us_per_pop_after_serving"] = float(engine.us_per_pop)
    print_rows(common.csv_row(
        "table8/frontier", 0.0,
        ";".join(f"({cf:.3f},{p99:.2f}ms)" for cf, p99 in frontier)))
    return results


if __name__ == "__main__":
    run()
