"""Shared benchmark corpus + timing utilities.

The paper's ALL corpus is 987 MB / 219M words — too large for this CPU
container, so benchmarks run on a statistically matched synthetic corpus
(Zipf unigrams, lognormal doc lengths; see text/corpus.py) at a --scale the
runner picks.  Word *strings* are synthesized with a realistic rank/length
profile so Table 1's compression ratio is measured against a meaningful
"original text size" (frequent words short, like English).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.engine import EngineConfig, SearchEngine
from repro.text import corpus


@dataclasses.dataclass
class Bench:
    """Shared benchmark state: one SearchEngine per corpus; the raw index /
    model / DRB bitmaps stay reachable for the *space* measurements (Table 1)
    while all query traffic goes through ``engine.search``."""
    cp: corpus.SyntheticCorpus
    engine: SearchEngine
    original_bytes: int
    build_s: float
    build_aux_s: float

    @property
    def idx(self):
        return self.engine.idx

    @property
    def model(self):
        return self.engine.model

    @property
    def aux(self):
        return self.engine.aux


def word_length(rank: int) -> int:
    """English-like: frequent words are short (the/of/and...), tail ~8-12."""
    return int(np.clip(1 + np.log2(rank + 2) * 0.9, 1, 14))


def original_text_bytes(cp: corpus.SyntheticCorpus, model) -> int:
    """Spaceless word model: word chars + one separator byte per token."""
    lens = np.array([word_length(int(model.rank_of_word[w])) + 1
                     for w in range(cp.vocab_size)], dtype=np.int64)
    total = 0
    for d in cp.doc_tokens:
        total += int(lens[d].sum())
    total += cp.n_docs * 2          # '$\n' document separators
    return total


def build(n_docs: int = 4000, mean_doc_len: int = 250, vocab: int = 40_000,
          seed: int = 0, block: int = 4096) -> Bench:
    cp = corpus.make_corpus(n_docs=n_docs, mean_doc_len=mean_doc_len,
                            vocab_size=vocab, seed=seed)
    t0 = time.time()
    engine = SearchEngine.build(cp, EngineConfig(block=block, eps=1e-6))
    t1 = time.time()
    engine.aux                    # force the lazy DRB bitmap build, timed
    t2 = time.time()
    return Bench(cp=cp, engine=engine,
                 original_bytes=original_text_bytes(cp, engine.model),
                 build_s=t1 - t0, build_aux_s=t2 - t1)


def time_fn(fn, reps: int = 3) -> float:
    """Median wall seconds of an already-compiled callable."""
    fn()                                      # warm (compile)
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        ts.append(time.time() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
