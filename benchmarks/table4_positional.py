"""Table 4 (beyond-paper): positional ranked retrieval — phrase and
proximity (near) top-k over the WTBC, ms/query by query length and window.

Phrase queries are n-grams lifted from the corpus itself (uniformly random
word tuples almost never co-occur adjacently, which would benchmark the empty
path); near queries reuse the same n-grams — tokens that do appear together —
across a sweep of window widths.  Everything runs through
``repro.engine.SearchEngine`` like Tables 2/3; per-query time is batch time /
batch size over compiled executors.
"""
from __future__ import annotations

from benchmarks import common
from repro.text import corpus


def ngram_queries(cp, n_queries: int, n_words: int, seed: int = 0):
    """Corpus n-grams under a df cap — the near sweep is O(sum occ), so
    Zipf-head stopword grams would benchmark the worst case, not the typical
    query."""
    return corpus.sample_ngram_queries(
        cp.doc_tokens, n_queries, n_words, seed=seed, df=cp.doc_freqs(),
        df_cap=max(2, cp.n_docs // 3))


def run(bench: common.Bench | None = None, *, n_queries: int = 16,
        words_list=(2, 3), ks=(10,), windows=(4, 16),
        print_rows=print) -> dict:
    b = bench or common.build()
    results = {}
    for n_words in words_list:
        qs = ngram_queries(b.cp, n_queries, n_words, seed=n_words)
        for k in ks:
            fn = lambda: b.engine.search(qs, k=k, mode="phrase").scores
            ms = common.time_fn(fn) / n_queries * 1e3
            name = f"table4/PHRASE_w{n_words}_k{k}"
            results[name] = ms
            print_rows(common.csv_row(name, ms * 1e3, f"{ms:.3f}ms/query"))
            for win in windows:
                fn = lambda: b.engine.search(qs, k=k, mode="near",
                                             window=win).scores
                ms = common.time_fn(fn) / n_queries * 1e3
                name = f"table4/NEAR{win}_w{n_words}_k{k}"
                results[name] = ms
                print_rows(common.csv_row(name, ms * 1e3, f"{ms:.3f}ms/query"))
    return results


if __name__ == "__main__":
    run()
