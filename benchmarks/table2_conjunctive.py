"""Paper Table 2: top-k weighted conjunctive (AND) queries — WTBC-DR vs
WTBC-DRB across document-frequency bands and query lengths.

Times are ms/query over jit-compiled query batches (batching via vmap is the
TPU-serving deployment shape; per-query time = batch time / batch size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import drb, ranked, scoring
from repro.text import corpus


def query_sets(b: common.Bench, bands: dict, n_queries: int, n_words: int):
    df_by_word = b.cp.doc_freqs()
    out = {}
    for name, band in bands.items():
        try:
            q = corpus.sample_queries(df_by_word, band, n_queries, n_words,
                                      seed=hash((name, n_words)) % 2**31)
        except ValueError:
            continue
        out[name] = b.model.rank_of_word[q]
    out["real"] = b.model.rank_of_word[
        corpus.zipf_real_queries(df_by_word, n_queries, n_words,
                                 seed=n_words)]
    return out


def run(bench: common.Bench | None = None, *, conjunctive: bool = True,
        n_queries: int = 16, words_list=(1, 2, 4), ks=(10,),
        band_names=("i", "ii", "iii"), print_rows=print) -> dict:
    b = bench or common.build()
    measure = scoring.TfIdf()
    idf = measure.idf(b.idx)
    N = int(b.idx.n_docs)
    bands = {k: v for k, v in corpus.fdoc_bands(N).items() if k in band_names}
    heap_cap = 2 * N + 4
    tag = "table2" if conjunctive else "table3"
    results = {}
    max_df = int(np.asarray(b.idx.df).max())

    for n_words in words_list:
        sets = query_sets(b, bands, n_queries, n_words)
        for band, qs in sets.items():
            words = jnp.asarray(qs, jnp.int32)
            wmask = jnp.ones_like(words, dtype=bool)
            for k in ks:
                # WTBC-DR
                fn = lambda: ranked.topk_dr_batch(
                    b.idx, words, wmask, idf, k=k, conjunctive=conjunctive,
                    heap_cap=heap_cap)
                dt = common.time_fn(fn)
                ms = dt / n_queries * 1e3
                name = f"{tag}/DR_band-{band}_w{n_words}_k{k}"
                results[name] = ms
                print_rows(common.csv_row(name, ms * 1e3, f"{ms:.3f}ms/query"))
                # WTBC-DRB
                df_q = np.asarray(b.idx.df)[qs].max()
                if conjunctive:
                    fnb = lambda: jax.vmap(
                        lambda w, m: drb.topk_drb_and(b.idx, b.aux, w, m,
                                                      measure, k=k))(words, wmask)
                else:
                    cap = int(min(max_df, df_q)) + 2
                    fnb = lambda: jax.vmap(
                        lambda w, m: drb.topk_drb_or(b.idx, b.aux, w, m,
                                                     measure, k=k,
                                                     max_df_cap=cap))(words, wmask)
                dtb = common.time_fn(fnb)
                msb = dtb / n_queries * 1e3
                name = f"{tag}/DRB_band-{band}_w{n_words}_k{k}"
                results[name] = msb
                print_rows(common.csv_row(name, msb * 1e3, f"{msb:.3f}ms/query"))
    return results


if __name__ == "__main__":
    run()
