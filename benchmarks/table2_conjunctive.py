"""Paper Table 2: top-k weighted conjunctive (AND) queries — WTBC-DR vs
WTBC-DRB across document-frequency bands and query lengths.

Both strategies run through ``repro.engine.SearchEngine`` — the benchmark
sends plain word-id query batches and picks ``strategy="dr"`` / ``"drb"``;
rank mapping, masks, heap/df caps, and executor caching are the facade's job.
Times are ms/query over jit-compiled query batches (batching is the
TPU-serving deployment shape; per-query time = batch time / batch size).
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.text import corpus


def query_sets(b: common.Bench, bands: dict, n_queries: int, n_words: int):
    """Word-id query batches per df band (+ a Zipf 'real log' mix)."""
    df_by_word = b.cp.doc_freqs()
    out = {}
    for name, band in bands.items():
        try:
            out[name] = corpus.sample_queries(df_by_word, band, n_queries,
                                              n_words,
                                              seed=hash((name, n_words)) % 2**31)
        except ValueError:
            continue
    out["real"] = corpus.zipf_real_queries(df_by_word, n_queries, n_words,
                                           seed=n_words)
    return out


def run(bench: common.Bench | None = None, *, conjunctive: bool = True,
        n_queries: int = 16, words_list=(1, 2, 4), ks=(10,),
        band_names=("i", "ii", "iii"), print_rows=print) -> dict:
    b = bench or common.build()
    mode = "and" if conjunctive else "or"
    bands = {name: v for name, v in corpus.fdoc_bands(b.cp.n_docs).items()
             if name in band_names}
    tag = "table2" if conjunctive else "table3"
    results = {}

    for n_words in words_list:
        sets = query_sets(b, bands, n_queries, n_words)
        for band, qs in sets.items():
            for k in ks:
                for strategy in ("dr", "drb"):
                    fn = lambda: b.engine.search(qs, k=k, mode=mode,
                                                 strategy=strategy).scores
                    dt = common.time_fn(fn)
                    ms = dt / n_queries * 1e3
                    name = f"{tag}/{strategy.upper()}_band-{band}_w{n_words}_k{k}"
                    results[name] = ms
                    print_rows(common.csv_row(name, ms * 1e3, f"{ms:.3f}ms/query"))
    return results


if __name__ == "__main__":
    run()
