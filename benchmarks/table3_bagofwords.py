"""Paper Table 3: top-k bag-of-words (OR) queries — WTBC-DR vs WTBC-DRB.
Same harness as Table 2 with the disjunctive semantics."""
from __future__ import annotations

from benchmarks import common, table2_conjunctive


def run(bench: common.Bench | None = None, **kw) -> dict:
    kw.setdefault("words_list", (2, 4))
    return table2_conjunctive.run(bench, conjunctive=False, **kw)


if __name__ == "__main__":
    run()
