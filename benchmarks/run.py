"""Benchmark runner: one function per paper table + roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip-distributed]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-complete sweep (bands i-iv, 1-6 words, k=10/20)")
    ap.add_argument("--skip-distributed", action="store_true")
    ap.add_argument("--docs", type=int, default=2500)
    ap.add_argument("--mean-doc-len", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=30_000)
    args = ap.parse_args()

    from benchmarks import (common, distributed_scaling, table1_compression,
                            table2_conjunctive, table3_bagofwords,
                            table4_positional)

    t0 = time.time()
    print("# building benchmark corpus ...", file=sys.stderr, flush=True)
    bench = common.build(n_docs=args.docs, mean_doc_len=args.mean_doc_len,
                         vocab=args.vocab)
    print(f"# corpus: {bench.cp.n_tokens} tokens, {bench.cp.n_docs} docs, "
          f"build {bench.build_s:.1f}s", file=sys.stderr, flush=True)

    print("name,us_per_call,derived")
    table1_compression.run(bench)

    if args.full:
        sweep = dict(n_queries=32, words_list=(1, 2, 3, 4, 6), ks=(10, 20),
                     band_names=("i", "ii", "iii", "iv"))
        sweep3 = dict(n_queries=32, words_list=(2, 3, 4, 6), ks=(10, 20),
                      band_names=("i", "ii", "iii", "iv"))
    else:
        sweep = dict(n_queries=16, words_list=(1, 2, 4), ks=(10,),
                     band_names=("i", "ii", "iii"))
        sweep3 = dict(n_queries=16, words_list=(2, 4), ks=(10,),
                      band_names=("i", "ii", "iii"))
    table2_conjunctive.run(bench, conjunctive=True, **sweep)
    table3_bagofwords.run(bench, **sweep3)
    if args.full:
        table4_positional.run(bench, n_queries=32, words_list=(2, 3, 4),
                              ks=(10, 20), windows=(4, 16, 64))
    else:
        table4_positional.run(bench)

    if not args.skip_distributed:
        distributed_scaling.run()

    # roofline summary (reads dry-run artifacts if present)
    try:
        from repro.analysis import roofline
        rows = roofline.load_all("single")
        for r in rows:
            if r.skipped:
                continue
            print(common.csv_row(
                f"roofline/{r.cell.replace(':', '__')}", 0.0,
                f"dom={r.dominant};frac={r.roofline_fraction():.3f}"))
    except Exception as e:  # artifacts absent: benches still usable
        print(f"# roofline artifacts unavailable: {e}", file=sys.stderr)

    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
