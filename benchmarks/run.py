"""Benchmark runner: one function per paper table + roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip-distributed]
                                            [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
``--json PATH`` additionally writes the rows as a machine-readable artifact
(``{"bench": {name: us_per_call}, "beam_sweep": {...}, "serving": {...},
"megabatch": {...}, "anytime": {...}}`` — the BENCH_PR10.json artifact that
carries the perf trajectory; beam-sweep entries hold iters/pops ratios vs
P=1, serving entries the table 6 throughput/percentile/cache metrics —
every serving entry now also carries the queue-wait/service percentile
split, and the ``open_obs`` entry the registry-derived per-stage latency
attribution (queue_wait/device/slice/total) plus the live WTBC roofline
gauges (bytes/query, achieved fraction per kernel backend) — megabatch
entries the table 7 skew/heavy-band tail latencies for mega vs lockstep vs
unbatched serving — anytime entries the table 8 budget ladder
(latency/recall/certified-fraction per rung) plus the served monotone
p99-vs-certified-fraction Pareto ``frontier``).  The artifact is also
mirrored into ``artifacts/`` so the committed trajectory and the CI upload
stay in one place.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-complete sweep (bands i-iv, 1-6 words, k=10/20)")
    ap.add_argument("--skip-distributed", action="store_true")
    ap.add_argument("--docs", type=int, default=2500)
    ap.add_argument("--mean-doc-len", type=int, default=200)
    ap.add_argument("--vocab", type=int, default=30_000)
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write results as a JSON artifact")
    args = ap.parse_args()

    from benchmarks import (common, distributed_scaling, table1_compression,
                            table2_conjunctive, table3_bagofwords,
                            table4_positional, table5_beam, table6_serving,
                            table7_megabatch, table8_anytime)

    rows: dict[str, float] = {}

    def collect(line: str) -> None:
        """Print a CSV row and record it for the --json artifact."""
        print(line)
        try:
            name, us, _derived = line.split(",", 2)
            rows[name] = float(us)
        except ValueError:
            pass

    t0 = time.time()
    print("# building benchmark corpus ...", file=sys.stderr, flush=True)
    bench = common.build(n_docs=args.docs, mean_doc_len=args.mean_doc_len,
                         vocab=args.vocab)
    print(f"# corpus: {bench.cp.n_tokens} tokens, {bench.cp.n_docs} docs, "
          f"build {bench.build_s:.1f}s", file=sys.stderr, flush=True)

    print("name,us_per_call,derived")
    table1_compression.run(bench, print_rows=collect)

    if args.full:
        sweep = dict(n_queries=32, words_list=(1, 2, 3, 4, 6), ks=(10, 20),
                     band_names=("i", "ii", "iii", "iv"))
        sweep3 = dict(n_queries=32, words_list=(2, 3, 4, 6), ks=(10, 20),
                      band_names=("i", "ii", "iii", "iv"))
    else:
        sweep = dict(n_queries=16, words_list=(1, 2, 4), ks=(10,),
                     band_names=("i", "ii", "iii"))
        sweep3 = dict(n_queries=16, words_list=(2, 4), ks=(10,),
                      band_names=("i", "ii", "iii"))
    table2_conjunctive.run(bench, conjunctive=True, print_rows=collect, **sweep)
    table3_bagofwords.run(bench, print_rows=collect, **sweep3)
    if args.full:
        table4_positional.run(bench, n_queries=32, words_list=(2, 3, 4),
                              ks=(10, 20), windows=(4, 16, 64),
                              print_rows=collect)
    else:
        table4_positional.run(bench, print_rows=collect)

    beam = table5_beam.run(bench, print_rows=collect,
                           with_sharded=not args.skip_distributed)
    serving = table6_serving.run(bench, print_rows=collect)
    megabatch = table7_megabatch.run(bench, print_rows=collect)
    anytime = table8_anytime.run(bench, print_rows=collect)

    if not args.skip_distributed:
        distributed_scaling.run(print_rows=collect)

    # roofline summary (reads dry-run artifacts if present)
    try:
        from repro.analysis import roofline
        for r in roofline.load_all("single"):
            if r.skipped:
                continue
            collect(common.csv_row(
                f"roofline/{r.cell.replace(':', '__')}", 0.0,
                f"dom={r.dominant};frac={r.roofline_fraction():.3f}"))
    except Exception as e:  # artifacts absent: benches still usable
        print(f"# roofline artifacts unavailable: {e}", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": rows, "beam_sweep": beam, "serving": serving,
                       "megabatch": megabatch, "anytime": anytime,
                       "config": {"docs": args.docs, "full": args.full}},
                      f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
        mirror = pathlib.Path(__file__).resolve().parent.parent / "artifacts"
        mirror.mkdir(exist_ok=True)
        target = mirror / pathlib.Path(args.json).name
        if target.resolve() != pathlib.Path(args.json).resolve():
            shutil.copy2(args.json, target)
            print(f"# mirrored to {target}", file=sys.stderr)

    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
