"""Table 7 (beyond-paper): megabatch continuous batching under skewed load.

Table 6 measures the coalescing frontend on uniform selective traffic;
this table measures the thing the re-landed megabatch core exists for —
**tail latency under work skew**.  Two bands over the shared benchmark
engine (profile ``dr/or/tfidf``, the mega-eligible path):

  skew  : 90% selective queries (df in [2, 8]) + 10% heavy ones (top-df
          words) — the regime where one heavy row inside a lockstep batch
          taxes every batch-mate with its full frontier;
  heavy : 100% heavy queries — the saturated regime.

Three servers per band, identical client concurrency:

  mega     : pool-frontier megabatch core + df-predicted work-bucket
             admission (heavy queries run alone) + EWMA-adaptive wait;
  lockstep : the vmapped-heap batch core, no admission lanes — the
             continuous-batching baseline mega must beat;
  single   : max_batch=1 — the no-batching floor.

Every pass runs post-warmup and asserts zero retraces (a compile on the
query path would drown the signal).  The JSON carries p50/p99 per
(server, band) — total plus the queue-wait/service decomposition, since
work skew shows up as queue growth long before it moves service time —
plus the skew-band p99 ratio ``lockstep / mega`` — the number
BENCH_PR7.json tracks (> 1 means mega wins the tail).
"""
from __future__ import annotations

from benchmarks import common
from repro.serve import QueryProfile, SearchServer, loadgen

N_LIGHT = 36
N_HEAVY = 4
WORDS = 3
MAX_BATCH = 16
WORKERS = 32
K = 10


def _traces(engine) -> int:
    return sum(engine.stats["traces"].values())


def _bands(engine, n_requests: int) -> dict[str, list]:
    n_docs = int(engine.n_docs)
    light = loadgen.sample_queries(engine, N_LIGHT, WORDS,
                                   df_range=(2, 8), seed=7)
    heavy = loadgen.sample_queries(engine, N_HEAVY, WORDS,
                                   df_range=(n_docs // 4, n_docs), seed=8)
    rng = __import__("numpy").random.default_rng(7)
    skew = [heavy[rng.integers(N_HEAVY)] if rng.random() < 0.10
            else light[rng.integers(N_LIGHT)] for _ in range(n_requests)]
    return {"skew": skew,
            "heavy": [heavy[i % N_HEAVY] for i in range(n_requests // 2)],
            "_warm": light + heavy}


def run(bench: common.Bench | None = None, *, n_requests: int = 512,
        print_rows=print) -> dict:
    b = bench or common.build()
    engine = b.engine
    bands = _bands(engine, n_requests)
    warm = bands.pop("_warm")
    results: dict = {"config": {"n_requests": n_requests, "words": WORDS,
                                "max_batch": MAX_BATCH, "workers": WORKERS,
                                "heavy_fraction": 0.10,
                                "profile": f"dr/or/tfidf/k{K}"}}

    servers = {
        "mega": dict(kw=dict(max_batch=MAX_BATCH, max_wait_ms=2.0,
                             cache_size=0, queue_depth=4 * WORKERS,
                             work_buckets=True, adaptive_wait=True),
                     profile=QueryProfile(mode="or", strategy="dr",
                                          measure="tfidf", k=K, mega=True)),
        "lockstep": dict(kw=dict(max_batch=MAX_BATCH, max_wait_ms=2.0,
                                 cache_size=0, queue_depth=4 * WORKERS),
                         profile=QueryProfile(mode="or", strategy="dr",
                                              measure="tfidf", k=K)),
        "single": dict(kw=dict(max_batch=1, max_wait_ms=0.0, cache_size=0,
                               queue_depth=4 * WORKERS),
                       profile=QueryProfile(mode="or", strategy="dr",
                                            measure="tfidf", k=K)),
    }

    for band, workload in bands.items():
        for name, spec in servers.items():
            srv = SearchServer(engine, **spec["kw"])
            srv.warmup(warm, spec["profile"])
            t0 = _traces(engine)
            with srv:
                loadgen.closed_loop(srv, workload[:2 * WORKERS],
                                    n_workers=WORKERS,
                                    profile=spec["profile"])   # warm pass
                rep = loadgen.closed_loop(srv, workload, n_workers=WORKERS,
                                          profile=spec["profile"],
                                          timeout_s=600.0)
            retraces = _traces(engine) - t0
            assert retraces == 0, \
                f"{retraces} retraces on the {name}/{band} query path"
            st = rep.server_stats
            tag = f"{band}_{name}"
            print_rows(common.csv_row(
                f"table7/{tag}", rep.mean_ms * 1e3,
                f"qps={rep.qps:.0f};p50={rep.p50_ms:.2f}ms;"
                f"p99={rep.p99_ms:.2f}ms;shed={rep.n_shed};"
                f"mean_batch={st['mean_batch']:.2f}"))
            results[tag] = {"qps": rep.qps, "p50_ms": rep.p50_ms,
                            "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
                            "mean_ms": rep.mean_ms, "shed": rep.n_shed,
                            "queue_p50_ms": rep.queue_p50_ms,
                            "queue_p99_ms": rep.queue_p99_ms,
                            "service_p50_ms": rep.service_p50_ms,
                            "service_p99_ms": rep.service_p99_ms,
                            "mean_batch": st["mean_batch"],
                            "batch_hist": st["batch_hist"]}

    for band in ("skew", "heavy"):
        ratio = (results[f"{band}_lockstep"]["p99_ms"]
                 / results[f"{band}_mega"]["p99_ms"])
        results[f"{band}_p99_lockstep_over_mega"] = ratio
        print_rows(common.csv_row(f"table7/{band}_p99_ratio", 0.0,
                                  f"lockstep_over_mega={ratio:.2f}x"))
    return results


if __name__ == "__main__":
    run()
