"""Table 6 (beyond-paper): online serving — micro-batching, caching, and
latency percentiles.

The paper's timing tables measure isolated queries; a server sees
*concurrent* traffic, and its numbers are distributional: sustained
throughput, p50/p95/p99 latency, batch-size mix, cache hit rate.  Four
passes over the shared benchmark engine:

  1. closed loop, micro-batcher ON  (max_batch=B, no cache)
  2. closed loop, one-query-at-a-time (max_batch=1, no cache) — the baseline
     the batcher must beat at equal client concurrency
  3. open loop at a fixed offered QPS (no cache) — latency under load
  4. closed loop over a Zipf-repeated workload with the cache ON
  5. open loop with the obs registry ENABLED on a DR profile (pops exist):
     per-stage latency attribution (queue_wait/device/slice/total) and the
     live WTBC roofline gauges, straight from the registry (DESIGN.md §10)

The workload is drawn from the selective band (low df, 2 words): the
interactive regime where per-call host overhead dominates and coalescing
pays.  Every pass runs after ``server.warmup`` and asserts the executor
trace counter stayed flat — serving must never compile on the query path.
Every report also carries the queue-wait/service percentile split, so a
regression in admission (queue grows) reads differently from one in the
engine (service grows).
"""
from __future__ import annotations

import numpy as np

import repro.obs as obs
from benchmarks import common
from repro.serve import QueryProfile, SearchServer, loadgen

N_DISTINCT = 48
WORDS = 2
MAX_BATCH = 32
WORKERS = 64


def _traces(engine) -> int:
    return sum(engine.stats["traces"].values())


def run(bench: common.Bench | None = None, *, n_requests: int = 768,
        open_qps: float = 200.0, print_rows=print) -> dict:
    b = bench or common.build()
    engine = b.engine
    queries = loadgen.sample_queries(engine, N_DISTINCT, WORDS,
                                     df_range=(2, 2), seed=7)
    profile = QueryProfile(mode="or", strategy="drb", measure="bm25", k=10,
                           df_cap=engine.suggested_df_cap(queries))
    workload = [queries[i % N_DISTINCT] for i in range(n_requests)]
    results: dict = {"config": {"n_requests": n_requests, "words": WORDS,
                                "max_batch": MAX_BATCH, "workers": WORKERS,
                                "profile": "drb/or/bm25/k10"}}

    def emit(tag: str, rep, extra: str = ""):
        st = rep.server_stats
        derived = (f"qps={rep.qps:.0f};p50={rep.p50_ms:.2f}ms;"
                   f"p95={rep.p95_ms:.2f}ms;p99={rep.p99_ms:.2f}ms;"
                   f"shed={rep.n_shed};mean_batch={st['mean_batch']:.2f}"
                   + (";" + extra if extra else ""))
        print_rows(common.csv_row(f"table6/{tag}", rep.mean_ms * 1e3, derived))
        results[tag] = {"qps": rep.qps, "p50_ms": rep.p50_ms,
                        "p95_ms": rep.p95_ms, "p99_ms": rep.p99_ms,
                        "mean_ms": rep.mean_ms, "shed": rep.n_shed,
                        "queue_p50_ms": rep.queue_p50_ms,
                        "queue_p99_ms": rep.queue_p99_ms,
                        "service_p50_ms": rep.service_p50_ms,
                        "service_p99_ms": rep.service_p99_ms,
                        "mean_batch": st["mean_batch"],
                        "batch_hist": st["batch_hist"],
                        "cache_hit_rate": st["cache"]["hit_rate"]}
        if rep.stages:
            results[tag]["stages"] = rep.stages

    # -- 1. micro-batched closed loop ---------------------------------------
    srv = SearchServer(engine, max_batch=MAX_BATCH, max_wait_ms=2.0,
                       cache_size=0, queue_depth=4 * WORKERS)
    srv.warmup(queries, profile)
    t0 = _traces(engine)
    with srv:
        loadgen.closed_loop(srv, workload[:2 * WORKERS], n_workers=WORKERS,
                            profile=profile)          # measurement warm pass
        rep_batched = loadgen.closed_loop(srv, workload, n_workers=WORKERS,
                                          profile=profile)
    retraces = _traces(engine) - t0
    emit("closed_batched", rep_batched, f"retraces={retraces}")
    results["retraces_after_warmup"] = retraces
    # the documented pin, not just a recording: a compile on the query path
    # costs ~1 s — it must fail the benchmark loudly, never hide in the JSON
    assert retraces == 0, f"{retraces} executor retraces on the query path"

    # -- 2. one-query-at-a-time baseline ------------------------------------
    srv1 = SearchServer(engine, max_batch=1, max_wait_ms=0.0,
                        cache_size=0, queue_depth=4 * WORKERS)
    srv1.warmup(queries, profile)
    with srv1:
        loadgen.closed_loop(srv1, workload[:2 * WORKERS], n_workers=WORKERS,
                            profile=profile)
        rep_single = loadgen.closed_loop(srv1, workload, n_workers=WORKERS,
                                         profile=profile)
    speedup = rep_batched.qps / rep_single.qps if rep_single.qps else float("nan")
    emit("closed_single", rep_single, f"batched_speedup={speedup:.2f}x")
    results["batched_vs_single_speedup"] = speedup

    # -- 3. open loop at fixed offered load ---------------------------------
    srv_o = SearchServer(engine, max_batch=MAX_BATCH, max_wait_ms=2.0,
                         cache_size=0, queue_depth=4 * WORKERS)
    srv_o.warmup(queries, profile)
    with srv_o:
        rep_open = loadgen.open_loop(
            srv_o, workload, target_qps=open_qps, profile=profile, seed=7)
    emit(f"open_qps{open_qps:.0f}", rep_open)

    # -- 4. Zipf workload with the result cache -----------------------------
    srv_c = SearchServer(engine, max_batch=MAX_BATCH, max_wait_ms=2.0,
                         cache_size=256, queue_depth=4 * WORKERS)
    srv_c.warmup(queries, profile)
    zipf = loadgen.zipf_workload(queries, n_requests, seed=7)
    with srv_c:
        rep_cache = loadgen.closed_loop(srv_c, zipf, n_workers=WORKERS,
                                        profile=profile)
    emit("closed_cached", rep_cache,
         f"hit_rate={rep_cache.server_stats['cache']['hit_rate']:.2f}")

    # -- 5. observability pass: registry stages + live roofline gauges ------
    # DR profile — the path that reports pops/padded, which is what feeds
    # the WTBC query-roofline attachment; tfidf keeps 'dr' legal.
    reg = obs.Registry(enabled=True)
    profile_dr = QueryProfile(mode="or", strategy="dr", measure="tfidf",
                              k=10)
    srv_m = SearchServer(engine, max_batch=MAX_BATCH, max_wait_ms=2.0,
                         cache_size=0, queue_depth=4 * WORKERS, registry=reg)
    srv_m.warmup(queries, profile_dr)
    try:
        with srv_m:
            rep_obs = loadgen.open_loop(
                srv_m, workload, target_qps=open_qps, profile=profile_dr,
                seed=7)
    finally:
        engine.obs_registry = None      # don't tax later benchmark passes
    emit("open_obs", rep_obs)
    assert rep_obs.stages and "device" in rep_obs.stages \
        and "queue_wait" in rep_obs.stages, \
        "obs-enabled pass produced no per-stage attribution"

    def _gauges(name: str) -> dict:
        return {dict(g.labels).get("backend", "?"): g.value
                for g in reg.find(name)}

    roofline = {"bytes_per_query": _gauges("repro_roofline_bytes_per_query"),
                "model_us_per_query":
                    _gauges("repro_roofline_model_us_per_query"),
                "achieved_frac": _gauges("repro_roofline_achieved_frac")}
    assert roofline["achieved_frac"], "no live roofline gauge was exported"
    results["open_obs"]["roofline"] = roofline
    frac = next(iter(roofline["achieved_frac"].values()))
    print_rows(common.csv_row("table6/open_obs_roofline", 0.0,
                              f"achieved_frac={frac:.2e}"))
    return results


if __name__ == "__main__":
    run()
