"""Beam sweep (DESIGN.md §6): frontier-batched Algorithm 1 at P ∈ {1,4,16,64}.

For each (mode, beam_width) cell the sweep reports

* ``us_per_call`` — wall-clock per query (batched, jit-compiled),
* ``iters``      — while-loop trips summed over the query batch.  This is
  the latency-chain length of the search: each trip is one round of
  sequentially dependent rank descents, so on hardware where the batched
  rank kernel amortizes (TPU), latency tracks iters, not pops,
* ``pops``       — segments actually popped; ``pop_overhead`` = pops(P) /
  pops(1) is the price of the beam (extra expansions the one-pop order
  would have avoided),
* ``iters_ratio`` = iters(1) / iters(P) — the recorded work-metric win,
* ``padded``     — dead beam lanes popped (frontier smaller than the active
  bucket); ``pad_frac`` = padded / (pops + padded) is the wasted-descent
  share the active-frontier buckets (core/ranked.py) are meant to crush,
* a roofline attachment (``analysis/roofline.py`` WTBC query-path model):
  ``bytes_per_query`` from levels x 2 ranks x Q x (tile + counter) traffic x
  (pops + padded), and ``roofline_frac`` = memory-bound floor / measured —
  how close the cell runs to the backend's bandwidth roofline.

A ``DRmega_*`` row benches the same queries through the pool-frontier
megabatch core (``mega=True``) — the path the fused device-resident beam
step (kernels/beam_step.py) replaces trip-for-trip under a gpu lowering.

The sharded sweep runs the same queries over a simulated 4-device mesh in a
subprocess (XLA locks the device count at first init, like
``distributed_scaling``).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks import common
from repro.analysis import roofline
from repro.engine.facade import pow2_bucket
from repro.kernels import backend as kernel_backend
from repro.text import corpus

BEAMS = (1, 4, 16, 64)

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import time, jax
    import numpy as np
    from repro.engine import EngineConfig, SearchEngine
    from repro.text import corpus

    cp = corpus.make_corpus(n_docs=%(docs)d, mean_doc_len=120,
                            vocab_size=10000, seed=0)
    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    qs = corpus.sample_queries(df, bands["ii"], %(nq)d, 3, seed=1)
    engine = SearchEngine.shard(cp, n_shards=4,
                                config=EngineConfig(with_drb=False))
    for P in %(beams)r:
        fn = lambda: engine.search(qs, k=10, mode="or", strategy="dr",
                                   beam_width=P)
        res = fn(); jax.block_until_ready(res.scores)      # compile
        t0 = time.time(); res = fn(); jax.block_until_ready(res.scores)
        dt = time.time() - t0
        d = res.diagnostics
        print(f"table5/sharded_DR_or_P{P},{dt/%(nq)d*1e6:.1f},"
              f"iters={int(np.sum(d['work']))};pops={int(np.sum(d['pops']))}")
""")


def run(bench: common.Bench | None = None, *, beams=BEAMS, n_queries: int = 16,
        n_words: int = 3, k: int = 10, with_sharded: bool = True,
        shard_docs: int = 800, print_rows=print) -> dict:
    b = bench or common.build()
    df = b.cp.doc_freqs()
    bands = corpus.fdoc_bands(b.cp.n_docs)
    qs = corpus.sample_queries(df, bands["ii"], n_queries, n_words, seed=5)
    results = {}

    qb = pow2_bucket(n_words)
    backend = kernel_backend.canonical_backend()
    block = b.engine.config.block

    def attach_roofline(rec: dict, us: float, pops: int, padded: int) -> str:
        rl = roofline.wtbc_query_roofline(
            backend=backend, measured_us_per_query=us,
            pops=pops / n_queries, padded=padded / n_queries,
            q=qb, block=block)
        rec.update(padded=padded,
                   pad_frac=padded / max(pops + padded, 1),
                   bytes_per_query=rl.bytes_per_query,
                   roofline_model_us=rl.model_us_per_query,
                   roofline_frac=rl.achieved_frac,
                   roofline_backend=backend)
        return (f"padded={padded};bytes/q={rl.bytes_per_query:.3g};"
                f"rl_frac={rl.achieved_frac:.4f}")

    cells = [("DR", m, "dr", "tfidf") for m in ("and", "or")]
    cells += [("DRB", "and", "drb", "bm25")]
    for tag, mode, strategy, measure in cells:
        base_iters = base_pops = None
        for P in beams:
            fn = lambda: b.engine.search(qs, k=k, mode=mode,
                                         strategy=strategy, measure=measure,
                                         beam_width=P)
            dt = common.time_fn(lambda: fn().scores)
            d = fn().diagnostics
            iters = int(np.sum(d["work"]))
            pops = int(np.sum(d["pops"]))
            padded = int(np.sum(d["padded"])) if "padded" in d else 0
            if P == beams[0]:
                base_iters, base_pops = max(iters, 1), max(pops, 1)
            us = dt / n_queries * 1e6
            name = f"table5/{tag}_{mode}_P{P}"
            results[name] = {"us_per_call": us, "iters": iters, "pops": pops,
                             "iters_ratio_vs_P1": base_iters / max(iters, 1),
                             "pop_overhead_vs_P1": pops / base_pops}
            rl_str = attach_roofline(results[name], us, pops, padded)
            derived = (f"iters={iters};pops={pops};"
                       f"iters_ratio={base_iters / max(iters, 1):.2f};"
                       f"pop_overhead={pops / base_pops:.2f};{rl_str}")
            print_rows(common.csv_row(name, us, derived))

    # pool-frontier megabatch core (DESIGN.md §8) — the path the fused
    # device-resident beam step replaces trip-for-trip on a gpu lowering
    for mode in ("and", "or"):
        fn = lambda: b.engine.search(qs, k=k, mode=mode, strategy="dr",
                                     measure="tfidf", mega=True)
        dt = common.time_fn(lambda: fn().scores)
        d = fn().diagnostics
        iters = int(np.sum(d["work"]))
        pops = int(np.sum(d["pops"]))
        padded = int(np.sum(d["padded"])) if "padded" in d else 0
        us = dt / n_queries * 1e6
        name = f"table5/DRmega_{mode}"
        results[name] = {"us_per_call": us, "iters": iters, "pops": pops}
        rl_str = attach_roofline(results[name], us, pops, padded)
        print_rows(common.csv_row(name, us,
                                  f"iters={iters};pops={pops};{rl_str}"))

    if with_sharded:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        script = SHARD_SCRIPT % {"docs": shard_docs, "nq": min(n_queries, 8),
                                 "beams": tuple(beams)}
        r = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                           capture_output=True, text=True, timeout=1800)
        for line in r.stdout.splitlines():
            if line.startswith("table5/"):
                print_rows(line)
                name, us, derived = line.split(",", 2)
                results[name] = {"us_per_call": float(us), "derived": derived}
        if r.returncode != 0:
            print_rows(f"table5/sharded_FAILED,0,{r.stderr[-200:]!r}")
    return results


if __name__ == "__main__":
    run()
