"""Distributed-index scaling (paper §5: "a cluster that implements a large
in-memory distributed index"): same corpus, 1 vs 8 document shards, batched
query latency.  Runs in a subprocess (needs 8 simulated host devices)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import time, jax
    from repro.engine import EngineConfig, SearchEngine
    from repro.text import corpus

    cp = corpus.make_corpus(n_docs=2000, mean_doc_len=150, vocab_size=20000, seed=0)
    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    qs = corpus.sample_queries(df, bands["ii"], 16, 3, seed=1)

    for n_shards in (1, 8):
        engine = SearchEngine.shard(cp, n_shards=n_shards,
                                    config=EngineConfig(with_drb=False))
        fn = lambda: engine.search(qs, k=10, mode="or", strategy="dr").scores
        jax.block_until_ready(fn())     # compile
        t0 = time.time(); jax.block_until_ready(fn()); dt = time.time() - t0
        print(f"distributed/dr-or_shards{n_shards},"
              f"{dt/16*1e6:.1f},{dt/16*1e3:.3f}ms/query")
""")


def run(print_rows=print):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=root,
                       capture_output=True, text=True, timeout=1800)
    for line in r.stdout.splitlines():
        if line.startswith("distributed/"):
            print_rows(line)
    if r.returncode != 0:
        print_rows(f"distributed/FAILED,0,{r.stderr[-200:]!r}")


if __name__ == "__main__":
    run()
