"""Paper Table 1: compression ratio (CR), construction time (CT), full
decompression time (DT) for WTBC-DR and WTBC-DRB.

Paper reference points (987 MB TREC corpus): CR 35.0% / 38.0%, i.e. the raw
(s,c)-DC stream is ~32.5% of the text, rank counters add ~2.5%, DRB bitmaps
~3%.  We report the same decomposition on the synthetic corpus.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import drb, wtbc


def run(bench: common.Bench | None = None, print_rows=print) -> dict:
    b = bench or common.build()
    rep = wtbc.space_report(b.idx)
    rep_aux = drb.space_report(b.aux)

    stream_bytes = rep["level_bytes"]
    counters = rep["rank_counters"]
    # word-level metadata (codeword tables etc.) is vocabulary-sized: the
    # paper counts it as negligible (Heaps' law); we report it explicitly.
    vocab_meta = rep["codeword_tables"] + rep["node_offsets"] + rep["df_occ_doclen"]
    sep = rep["sep_positions"]
    dr_total = stream_bytes + counters + sep
    drb_total = dr_total + rep_aux["bitmap_bits_bytes"] + rep_aux["bitmap_counters"]

    t0 = time.time()
    full = wtbc.decode_all_np(b.idx, b.model)
    dt = time.time() - t0
    assert len(full) == b.cp.n_tokens

    O = b.original_bytes
    rows = {
        "table1/scdc_stream_CR_pct": 100.0 * stream_bytes / O,
        "table1/rank_counters_pct": 100.0 * counters / O,
        "table1/wtbc_dr_CR_pct": 100.0 * dr_total / O,
        "table1/wtbc_drb_CR_pct": 100.0 * drb_total / O,
        "table1/vocab_metadata_pct": 100.0 * vocab_meta / O,
        "table1/dr_extra_over_stream_pct": 100.0 * (dr_total - stream_bytes) / stream_bytes,
        "table1/drb_extra_over_stream_pct": 100.0 * (drb_total - stream_bytes) / stream_bytes,
        "table1/CT_s": b.build_s + b.build_aux_s,
        "table1/DT_s": dt,
        "table1/tokens": float(b.cp.n_tokens),
        "table1/original_MB": O / 1e6,
    }
    for k, v in rows.items():
        print_rows(common.csv_row(k, 0.0, f"{v:.3f}"))
    return rows


if __name__ == "__main__":
    run()
