"""Query results returned by :meth:`repro.engine.SearchEngine.search`.

A thin, backend-agnostic wrapper over the kernels' ``DRResult`` leaves: doc
ids / scores are always batched ``(B, k)`` device arrays (a single query is a
batch of one), plus the work counters the benchmarks report and the resolved
routing metadata (which strategy ``"auto"`` actually picked, which measure
scored, …) so callers never have to reverse-engineer the dispatch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SearchResults:
    """Top-k answers for a batch of queries.

    docs:    (B, k) int32 global document ids, -1 padded past ``n_found``.
    scores:  (B, k) float32, descending, -inf padded.
    n_found: (B,)   int32 documents actually found per query.
    work:    (B,)   int32 backend work counter — DR: queue pops (summed over
             shards when sharded); DRB/AND: candidate iterations; DRB/OR: the
             df cap the gather ran with.
    k / mode / strategy / measure: the resolved query parameters (``strategy``
             is post-"auto" routing, never "auto" itself).
    match_pos / match_len: positional payloads, present for the "phrase" /
             "near" modes only (None otherwise).  ``match_pos`` is the
             (B, k) doc-relative token offset of the first phrase match /
             of the minimal proximity window; ``match_len`` its width in
             tokens; both -1 padded past ``n_found``.
    beam_width: the frontier width the executor ran with (1 on loop-free
             paths).
    pops:    (B,) int32 segments / candidates actually examined (None on the
             positional paths) — together with ``work`` (loop trips) this is
             the beam's emitted-doc-overhead metric.
    overflowed: (B,) bool — a search heap dropped a push at capacity; the
             affected query's ranking may be incomplete and should not be
             trusted silently.  See :meth:`diagnostics`.
    padded:  (B,) int32 — dead beam lanes processed (pad-waste): pops +
             padded = lanes the loop actually paid for.  The active-frontier
             buckets (core/ranked.py) keep this near zero; None on paths
             without beam padding.
    certified: (B, k) bool — anytime certification (DESIGN.md §11): a True
             slot provably equals the exact oracle's slot; always a prefix
             per row, and all-True whenever the search ran to completion.
             None on the positional paths (which are always exhaustive).
    score_bound: (B,) float32 — per-row score upper bound on every document
             NOT in ``docs`` (-inf when the frontier was exhausted); the
             honest "how wrong can the uncertified tail be" dial.  None on
             the positional paths.
    sla:     the resolved SLA class this search ran under ("exact",
             "bounded", or "best_effort" — see engine/config.SLA_CLASSES).
    """
    docs: jnp.ndarray
    scores: jnp.ndarray
    n_found: jnp.ndarray
    work: jnp.ndarray
    k: int
    mode: str
    strategy: str
    measure: str
    match_pos: jnp.ndarray | None = None
    match_len: jnp.ndarray | None = None
    beam_width: int = 1
    pops: jnp.ndarray | None = None
    overflowed: jnp.ndarray | None = None
    padded: jnp.ndarray | None = None
    certified: jnp.ndarray | None = None
    score_bound: jnp.ndarray | None = None
    sla: str = "exact"

    def __post_init__(self):
        if self.docs.ndim != 2 or self.scores.shape != self.docs.shape:
            raise ValueError(f"expected batched (B, k) results, got docs "
                             f"{self.docs.shape} / scores {self.scores.shape}")
        for a in (self.match_pos, self.match_len):
            if a is not None and a.shape != self.docs.shape:
                raise ValueError(f"match payload shape {a.shape} != docs "
                                 f"shape {self.docs.shape}")

    def __len__(self) -> int:
        """Number of queries in the batch."""
        return int(self.docs.shape[0])

    def hits(self, b: int = 0) -> list[tuple[int, float]]:
        """Found ``(doc_id, score)`` pairs of query ``b``, best first."""
        n = int(self.n_found[b])
        docs = np.asarray(self.docs[b])[:n]
        scores = np.asarray(self.scores[b])[:n]
        return [(int(d), float(s)) for d, s in zip(docs, scores)]

    def matches(self, b: int = 0) -> list[tuple[int, float, int, int]]:
        """Found ``(doc_id, score, match_pos, match_len)`` tuples of query
        ``b``, best first — positional ("phrase" / "near") results only."""
        if self.match_pos is None or self.match_len is None:
            raise ValueError(f"mode={self.mode!r} results carry no match "
                             "positions; use .hits() (positions exist for "
                             "the 'phrase' and 'near' modes only)")
        n = int(self.n_found[b])
        return [(int(d), float(s), int(p), int(l)) for d, s, p, l in zip(
            np.asarray(self.docs[b])[:n], np.asarray(self.scores[b])[:n],
            np.asarray(self.match_pos[b])[:n], np.asarray(self.match_len[b])[:n])]

    def doc_ids(self) -> np.ndarray:
        """(B, k) numpy view of the document ids (-1 padded)."""
        return np.asarray(self.docs)

    def certified_fraction(self) -> float:
        """Certified slots / found slots over the whole batch (1.0 when the
        backend reports no certification data — exhaustive paths are exact)."""
        if self.certified is None:
            return 1.0
        found = int(np.sum(np.asarray(self.n_found)))
        if found == 0:
            return 1.0
        return float(np.sum(np.asarray(self.certified))) / found

    @property
    def diagnostics(self) -> dict:
        """Per-query health/work counters as host arrays.

        Keys: ``work`` (loop trips), ``beam_width``, and — when the backend
        reports them — ``pops`` (segments/candidates examined),
        ``overflowed`` (heap-capacity drops; a True entry means that query's
        ranking may be incomplete and the engine should be rebuilt with a
        larger ``heap_cap`` or queried with a smaller k) and ``padded``
        (dead beam lanes paid for — the pad-waste metric of the
        active-frontier buckets)."""
        out = {"work": np.asarray(self.work), "beam_width": self.beam_width,
               "sla": self.sla}
        if self.pops is not None:
            out["pops"] = np.asarray(self.pops)
        if self.overflowed is not None:
            out["overflowed"] = np.asarray(self.overflowed)
        if self.padded is not None:
            out["padded"] = np.asarray(self.padded)
        if self.certified is not None:
            out["certified"] = np.asarray(self.certified)
            out["certified_fraction"] = self.certified_fraction()
        if self.score_bound is not None:
            out["score_bound"] = np.asarray(self.score_bound)
        return out
