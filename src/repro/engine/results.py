"""Query results returned by :meth:`repro.engine.SearchEngine.search`.

A thin, backend-agnostic wrapper over the kernels' ``DRResult`` leaves: doc
ids / scores are always batched ``(B, k)`` device arrays (a single query is a
batch of one), plus the work counters the benchmarks report and the resolved
routing metadata (which strategy ``"auto"`` actually picked, which measure
scored, …) so callers never have to reverse-engineer the dispatch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SearchResults:
    """Top-k answers for a batch of queries.

    docs:    (B, k) int32 global document ids, -1 padded past ``n_found``.
    scores:  (B, k) float32, descending, -inf padded.
    n_found: (B,)   int32 documents actually found per query.
    work:    (B,)   int32 backend work counter — DR: queue pops (summed over
             shards when sharded); DRB/AND: candidate iterations; DRB/OR: the
             df cap the gather ran with.
    k / mode / strategy / measure: the resolved query parameters (``strategy``
             is post-"auto" routing, never "auto" itself).
    """
    docs: jnp.ndarray
    scores: jnp.ndarray
    n_found: jnp.ndarray
    work: jnp.ndarray
    k: int
    mode: str
    strategy: str
    measure: str

    def __post_init__(self):
        if self.docs.ndim != 2 or self.scores.shape != self.docs.shape:
            raise ValueError(f"expected batched (B, k) results, got docs "
                             f"{self.docs.shape} / scores {self.scores.shape}")

    def __len__(self) -> int:
        """Number of queries in the batch."""
        return int(self.docs.shape[0])

    def hits(self, b: int = 0) -> list[tuple[int, float]]:
        """Found ``(doc_id, score)`` pairs of query ``b``, best first."""
        n = int(self.n_found[b])
        docs = np.asarray(self.docs[b])[:n]
        scores = np.asarray(self.scores[b])[:n]
        return [(int(d), float(s)) for d, s in zip(docs, scores)]

    def doc_ids(self) -> np.ndarray:
        """(B, k) numpy view of the document ids (-1 padded)."""
        return np.asarray(self.docs)
