"""repro.engine — the unified ranked-retrieval query facade.

One API over every backend the repo implements: WTBC-DR (ranked retrieval in
no extra space, paper §3.1), WTBC-DRB (small tf bitmaps, §3.2), and the
document-sharded mesh deployment (core/distributed.py).

    from repro.engine import SearchEngine

    engine = SearchEngine.build(doc_tokens)       # or SearchEngine.shard(...)
    results = engine.search(queries, k=10, mode="and", measure="bm25")
    engine.snippets(results, length=8)

See :class:`SearchEngine` for the full contract, :class:`EngineConfig` for
build knobs, and :class:`SearchResults` for the result object.
"""
from repro.engine.config import EngineConfig
from repro.engine.facade import (MEASURES, MODES, POSITIONAL_MODES,
                                 STRATEGIES, SearchEngine)
from repro.engine.results import SearchResults

__all__ = ["EngineConfig", "SearchEngine", "SearchResults",
           "MEASURES", "MODES", "POSITIONAL_MODES", "STRATEGIES"]
