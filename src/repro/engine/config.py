"""Build-time configuration for the :class:`repro.engine.SearchEngine` facade.

Everything here is a *build* knob (index layout, DRB bitmap policy); query-time
knobs (k, mode, strategy, measure, budget) are ``SearchEngine.search``
arguments so one built engine serves every workload shape.
"""
from __future__ import annotations

import dataclasses

from repro.core import bytemap

# SLA degradation ladder (DESIGN.md §11), best to worst:
#   exact       — run to completion, every slot provably the oracle's slot;
#                 deadlines are rejected (an exact search cannot be cut short)
#   bounded     — honor an anytime budget / wall deadline; results carry
#                 per-slot certified bits + a score upper bound for the rest
#   best_effort — like bounded, but the serving layer may shrink the budget
#                 further under load instead of shedding
# (shedding is the serving layer's fourth rung — the engine never sheds.)
SLA_CLASSES = ("exact", "bounded", "best_effort")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for ``SearchEngine.build`` / ``SearchEngine.shard``.

    block:     rank-counter block size of every ByteMap level (space/speed
               trade of the partial counters, paper §2.3).
    eps:       DRB stopword threshold — words with idf < eps get no tf bitmap
               (paper: 1e-6 filters only near-universal words).
    with_drb:  whether the DRB auxiliary bitmaps may be built.  The single
               backend builds them *lazily* on the first DRB-routed query, so
               a DR-only deployment pays no bitmap space; the sharded backend
               stacks them *eagerly* at build time (rectangular pytree).
               ``with_drb=False`` skips/forbids the build on both backends —
               and therefore BM25 / explicit ``strategy="drb"`` queries.
    default_k: results per query when ``search`` is called without ``k``.
    default_window: proximity width (tokens) when ``search(mode="near")`` is
               called without ``window``.  Dynamic at query time — changing
               it never recompiles an executor.
    default_beam_width: frontier width P of the DR / DRB-AND search loops
               when ``search`` is called without ``beam_width`` (DESIGN.md
               §6).  P is *static* per executor — like ``k``, each distinct
               width compiles (and caches) its own program; P=1 is the
               classical one-pop Algorithm 1.
    default_mega: route batched DR and/or queries through the pool-frontier
               megabatch core (``core/mega.py``, DESIGN.md §8) when
               ``search`` is called without ``mega``.  Row-for-row bitwise
               equal to the serial core at the same Q bucket; ignored by
               the paths the mega core does not cover (DRB, positional,
               sharded).  Old snapshots restore with the default (False).
    kernel_backend: lowering request for the Pallas descent kernels
               (``kernels/backend.py``, DESIGN.md §9): "auto" picks the
               host's accelerator (TPU DMA-gather kernel, Triton on GPU)
               and the vectorized jnp reference elsewhere; explicit values
               ("tpu", "gpu", "ref", "gpu:interpret", …) pin the lowering —
               e.g. "gpu:interpret" drives the fused device-resident beam
               step through the Pallas interpreter on any host (the CI
               parity configuration).  Resolved once per search into the
               executor key, so a changed force/env never serves a stale
               compiled program.
    default_sla: the SLA class ``search`` assumes when called without ``sla``
               and without any anytime knob (``budget`` / ``deadline_ms``
               auto-promote "exact" to "bounded"); one of ``SLA_CLASSES``.
    """
    block: int = bytemap.DEFAULT_BLOCK
    eps: float = 1e-6
    with_drb: bool = True
    default_k: int = 10
    default_window: int = 8
    default_beam_width: int = 1
    default_mega: bool = False
    kernel_backend: str = "auto"
    default_sla: str = "exact"

    def __post_init__(self):
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")
        from repro.kernels import backend as _kb
        if self.kernel_backend not in _kb.VALID_REQUESTS:
            raise ValueError(f"kernel_backend must be one of "
                             f"{_kb.VALID_REQUESTS}, got "
                             f"{self.kernel_backend!r}")
        if self.default_k <= 0:
            raise ValueError(f"default_k must be positive, got {self.default_k}")
        if self.default_window <= 0:
            raise ValueError(f"default_window must be positive, got "
                             f"{self.default_window}")
        if self.default_beam_width <= 0:
            raise ValueError(f"default_beam_width must be positive, got "
                             f"{self.default_beam_width}")
        if self.default_sla not in SLA_CLASSES:
            raise ValueError(f"default_sla must be one of {SLA_CLASSES}, "
                             f"got {self.default_sla!r}")
