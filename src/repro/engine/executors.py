"""Jitted query executors behind the :class:`repro.engine.SearchEngine` facade.

One executor = one ``jax.jit``-compiled callable specialized on everything XLA
needs static: ``(backend, strategy, mode, measure, k, batch_shape, budget)``.
The facade caches executors by exactly that key, so repeated traffic with the
same shape hits an already-compiled program — the single place the ROADMAP's
serving path gets its compile-once/run-many behavior.

Trace accounting: each executor's Python body runs only when jax *traces* it
(once per compilation), so the ``note()`` callback it invokes counts actual
retraces.  ``SearchEngine.stats["traces"]`` exposes the counters and
``tests/test_engine.py`` pins the cache behavior with them.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.core import distributed, drb, mega, positional, ranked


class ExecutorKey(NamedTuple):
    """Hashable cache key — everything that forces a distinct XLA program.

    The positional modes ("phrase" / "near") get distinct keys through
    ``mode``; the proximity ``window`` is deliberately *not* part of the key —
    it is a traced scalar, so every window width shares one compiled program.
    """
    backend: str          # "single" | "sharded"
    strategy: str         # "dr" | "drb" (post-"auto" resolution)
    mode: str             # "and" | "or" | "phrase" | "near"
    measure: Any          # frozen scoring dataclass (hashable, carries params)
    k: int
    batch_shape: tuple[int, int]   # (B, Q)
    budget: int | None    # DR max_pops
    df_cap: int | None    # DRB/OR gather width (pow2-bucketed); None otherwise
    beam_width: int       # frontier width P of the DR / DRB-AND loop cores;
                          # static (a distinct P is a distinct XLA program),
                          # normalized to 1 on the paths with no search loop
    mega: bool = False    # single-backend DR and/or only: run the batch on
                          # the pool-frontier core (core/mega.py) instead of
                          # vmapping the serial heap core; normalized False
                          # everywhere else so keys never split spuriously
    lowering: str = "ref" # resolved descent-kernel plan tag
                          # (kernels/backend.py KernelPlan.tag — "tpu",
                          # "gpu", "gpu:interpret", "ref", …): part of the
                          # key so a changed force/env/config can never hit
                          # an executor compiled under another lowering; on
                          # the mega path a "gpu*" tag additionally routes
                          # the loop body through the fused device-resident
                          # beam step (kernels/beam_step.py)


def make_single_dr(key: ExecutorKey, *, heap_cap: int, mega_cap: int, note):
    """(idx, words, wmask, idf) -> DRResult with (B, k) leaves."""
    conjunctive = key.mode == "and"

    if key.mega:
        # a gpu-kind lowering replaces the whole loop trip with ONE fused
        # beam-step launch; "tpu"/"ref" keep the jnp pool body (the descent
        # inside it still dispatches through kernels/ops.py)
        fused = key.lowering if key.lowering.startswith("gpu") else None

        def fn(idx, words, wmask, idf):
            note()
            return mega.topk_dr_mega(idx, words, wmask, idf, k=key.k,
                                     conjunctive=conjunctive, cap=mega_cap,
                                     max_pops=key.budget, fused=fused)
    else:
        def fn(idx, words, wmask, idf):
            note()
            return ranked.topk_dr_batch(idx, words, wmask, idf, k=key.k,
                                        conjunctive=conjunctive,
                                        heap_cap=heap_cap,
                                        max_pops=key.budget,
                                        beam_width=key.beam_width)

    return jax.jit(fn)


def make_single_drb(key: ExecutorKey, *, note):
    """(idx, aux, words, wmask, idf, avg_dl) -> DRResult with (B, k) leaves."""
    measure = key.measure
    if key.mode == "and":
        def one(idx, aux, w, m, idf, avg_dl):
            return drb.topk_drb_and(idx, aux, w, m, measure, k=key.k,
                                    idf=idf, avg_dl=avg_dl,
                                    beam_width=key.beam_width,
                                    max_pops=key.budget)
    else:
        def one(idx, aux, w, m, idf, avg_dl):
            return drb.topk_drb_or(idx, aux, w, m, measure, k=key.k,
                                   max_df_cap=key.df_cap, idf=idf,
                                   avg_dl=avg_dl)

    def fn(idx, aux, words, wmask, idf, avg_dl):
        note()
        return jax.vmap(
            lambda w, m: one(idx, aux, w, m, idf, avg_dl))(words, wmask)

    return jax.jit(fn)


def make_single_positional(key: ExecutorKey, *, note):
    """(idx, words, wmask, idf, window, avg_dl) -> PositionalResult with
    (B, k) leaves.  ``window`` is a traced int32 scalar (ignored by phrase),
    so proximity widths never force a retrace."""
    phrase = key.mode == "phrase"
    measure = key.measure

    def fn(idx, words, wmask, idf, window, avg_dl):
        note()
        return positional.topk_positional_batch(
            idx, words, wmask, idf, k=key.k, phrase=phrase, measure=measure,
            window=window, avg_dl=avg_dl)

    return jax.jit(fn)


def make_sharded(key: ExecutorKey, *, mesh, shard_axes, heap_cap: int, note):
    """(sharded, words, wmask, idf) -> DRResult with (B, k) leaves.  ``idf``
    is the measure-specific *global* table so sharded scores match the
    single-host backend for every measure, not just tf-idf."""
    method = f"{key.strategy}-{key.mode}"

    def fn(sharded, words, wmask, idf):
        note()
        return distributed.distributed_topk(
            sharded, words, wmask, k=key.k, method=method, mesh=mesh,
            shard_axes=shard_axes, heap_cap=heap_cap,
            max_df_cap=key.df_cap or 2, max_pops=key.budget,
            measure=key.measure, idf=idf, beam_width=key.beam_width)

    return jax.jit(fn)
