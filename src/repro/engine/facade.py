"""`SearchEngine` — the one public query API over every retrieval backend.

The paper frames WTBC-DR ("no extra space") and WTBC-DRB ("a few small
bitmaps") as interchangeable strategies answering the same ranked top-k
queries; the repo additionally runs both over document-sharded device meshes.
Before this facade, every caller re-assembled the same glue by hand: word-id
-> frequency-rank mapping, ragged-query padding and masking, idf tables,
``heap_cap`` / ``max_df_cap`` derivation, DR/BM25 compatibility checks, vmap
wiring, shard merges.  ``SearchEngine`` owns all of it:

    engine = SearchEngine.build(doc_tokens)            # or .shard(..., n_shards=8)
    res = engine.search([[w1, w2], [w3]], k=10, mode="and")
    print(res.hits(0), engine.snippets(res, length=8))
    res = engine.search([[w1, w2]], mode="phrase")     # or mode="near", window=6
    print(res.matches(0))                              # (doc, score, pos, len)

Dispatch goes through jitted executors cached by
``(strategy, mode, measure, k, batch_shape, budget, df_cap)`` (see
executors.py), so steady-state traffic never retraces.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import types
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import distributed, drb, positional, scoring, wtbc
from repro.engine import executors
from repro.kernels import backend as kernel_backend
from repro.engine.config import EngineConfig, SLA_CLASSES
from repro.engine.results import SearchResults

MODES = ("and", "or", "phrase", "near")
POSITIONAL_MODES = ("phrase", "near")
STRATEGIES = ("dr", "drb", "auto")
MEASURES = {"tfidf": scoring.TfIdf(), "bm25": scoring.BM25()}

# cold-start pop cost (µs) assumed by the deadline -> budget conversion until
# the engine has observed real traffic (see SearchEngine.us_per_pop);
# deliberately pessimistic so a deadline is honored even before warmup
DEFAULT_US_PER_POP = 50.0


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1) — the shared shape-bucket policy:
    executor keys quantize the query-word dim Q (and the serving batcher the
    batch dim B) to these buckets, so mixed traffic reuses a small fixed set
    of compiled programs instead of one program per exact shape."""
    return 1 << max(0, int(n) - 1).bit_length()


def budget_bucket(n: int) -> int:
    """Largest power of FOUR <= n (n >= 1) — the anytime-budget quantizer.
    ``budget`` is static in the executor key (the loop bound is compiled in),
    so a deadline-derived budget — which drifts with the live us/pop estimate
    — must be quantized or every estimate update would compile a fresh
    program.  Powers of four keep the whole useful range [1, 2*n_docs) within
    a handful of buckets while never overshooting the deadline (floor, not
    ceil: rounding the budget *down* can only finish earlier)."""
    n = max(1, int(n))
    return 1 << ((n.bit_length() - 1) & ~1)


def _normalize_docs(docs, vocab_size: int | None):
    """Accept a corpus object (``.doc_tokens`` / ``.vocab_size``) or a plain
    list of per-document word-id arrays; return (list[np.ndarray], vocab_size).
    Word id 0 is the reserved document separator '$'."""
    if hasattr(docs, "doc_tokens") and hasattr(docs, "vocab_size"):
        if vocab_size is not None and vocab_size < int(docs.vocab_size):
            raise ValueError(f"vocab_size={vocab_size} smaller than the "
                             f"corpus's own vocab_size={docs.vocab_size}")
        return list(docs.doc_tokens), int(vocab_size or docs.vocab_size)
    doc_tokens = [np.asarray(d, dtype=np.int64) for d in docs]
    if not doc_tokens:
        raise ValueError("cannot build an engine over zero documents")
    max_id = max((int(d.max()) for d in doc_tokens if len(d)), default=0)
    for d in doc_tokens:
        if len(d) and int(d.min()) < 1:
            raise ValueError("word id 0 is reserved for the '$' separator; "
                             "document ids must be >= 1")
    if vocab_size is None:
        vocab_size = max_id + 1
    elif vocab_size <= max_id:
        raise ValueError(f"vocab_size={vocab_size} too small for max word id "
                         f"{max_id}")
    return doc_tokens, int(vocab_size)


class SearchEngine:
    """Facade over the DR / DRB / sharded retrieval backends.

    Construct with :meth:`build` (single index) or :meth:`shard`
    (document-sharded mesh); query with :meth:`search`; recover text around
    the hits with :meth:`snippets`.  Instances are cheap handles around
    immutable device arrays — share one per corpus.
    """

    def __init__(self, *, _token=None, config, model, n_docs, backend,
                 idx=None, doc_tokens=None, sharded=None, mesh=None,
                 shard_axes=None):
        if _token is not _CTOR_TOKEN:
            raise TypeError("use SearchEngine.build(...) or "
                            "SearchEngine.shard(...)")
        self.config = config
        self.model = model
        self.n_docs = n_docs
        self.backend = backend                  # "single" | "sharded"
        self._idx = idx
        # kept only until the lazy DRB build can no longer happen — pinning
        # the raw tokens forever would defeat the paper's "no space" premise
        self._doc_tokens = doc_tokens if config.with_drb else None
        self._aux = None
        self._sharded = sharded
        self._mesh = mesh
        self._shard_axes = shard_axes
        self._idf_tables: dict[str, jnp.ndarray] = {}
        self._avg_dl = None
        self._executors: dict[executors.ExecutorKey, Any] = {}
        self._trace_counts: dict[executors.ExecutorKey, int] = {}
        self._us_per_pop: float | None = None   # EWMA, None until observed
        self._stats_lock = threading.Lock()     # _executors/_trace_counts/EWMA
        # None -> record into the live process default (obs.enable()/use());
        # the serving frontend pins its own registry here on adoption
        self.obs_registry: "obs.Registry | None" = None
        self._shard_slices: dict[int, wtbc.WTBCIndex] = {}
        if backend == "single":
            self._heap_cap = 2 * int(idx.n_docs) + 4
            self._df_np = np.asarray(idx.df)
            # pool-frontier cap: the split tree's frontier holds <= n_docs
            # segments (each split removes 1, adds <= 2, over < n_docs
            # splits), so n_docs + 2 can never overflow (DESIGN.md §8)
            self._mega_cap = int(idx.n_docs) + 2
        else:
            self._heap_cap = 2 * int(np.max(np.asarray(sharded.idx.n_docs))) + 4
            # per-word max over shards: any shard's DRB/OR gather fits the cap
            self._df_np = np.asarray(sharded.idx.df).max(axis=0)
            self._mega_cap = 0          # mega covers the single backend only
        self._max_df_cap = int(self._df_np.max()) + 2
        self._content_tag: int | None = None

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, docs, config: EngineConfig | None = None, *,
              vocab_size: int | None = None) -> "SearchEngine":
        """Build a single-host engine over ``docs`` (a corpus object or a list
        of per-document word-id arrays, ids >= 1)."""
        config = config or EngineConfig()
        doc_tokens, vocab_size = _normalize_docs(docs, vocab_size)
        idx, model = wtbc.build_index(doc_tokens, vocab_size,
                                      block=config.block)
        return cls(_token=_CTOR_TOKEN, config=config, model=model,
                   n_docs=len(doc_tokens), backend="single", idx=idx,
                   doc_tokens=doc_tokens)

    @classmethod
    def shard(cls, docs, n_shards: int, config: EngineConfig | None = None, *,
              vocab_size: int | None = None, mesh=None,
              shard_axes: str | tuple[str, ...] = "shards") -> "SearchEngine":
        """Build a document-sharded engine: one WTBC per device along
        ``shard_axes`` of ``mesh`` (a 1-D mesh over the first ``n_shards``
        local devices when ``mesh`` is omitted), global (s,c)-DC code and
        global idf so shard scores merge exactly."""
        config = config or EngineConfig()
        doc_tokens, vocab_size = _normalize_docs(docs, vocab_size)
        sharded, model = distributed.build_sharded(
            doc_tokens, vocab_size, n_shards=n_shards, block=config.block,
            with_drb=config.with_drb, eps=config.eps)
        if mesh is None:
            axes = (shard_axes,) if isinstance(shard_axes, str) else tuple(shard_axes)
            if len(axes) != 1:
                raise ValueError("pass an explicit mesh for multi-axis sharding")
            devices = jax.devices()
            if len(devices) < n_shards:
                raise ValueError(f"n_shards={n_shards} exceeds the "
                                 f"{len(devices)} available devices; pass a mesh")
            mesh = jax.sharding.Mesh(
                np.array(devices[:n_shards]).reshape(n_shards), axes)
        return cls(_token=_CTOR_TOKEN, config=config, model=model,
                   n_docs=len(doc_tokens), backend="sharded", sharded=sharded,
                   mesh=mesh, shard_axes=shard_axes)

    @classmethod
    def _restore(cls, *, config, model, n_docs, backend, idx=None, aux=None,
                 sharded=None, mesh=None, shard_axes=None) -> "SearchEngine":
        """Reassemble an engine from snapshot parts (``repro.serve.snapshot``)
        — no corpus, no rebuild; the restored arrays ARE the engine."""
        self = cls(_token=_CTOR_TOKEN, config=config, model=model,
                   n_docs=n_docs, backend=backend, idx=idx, doc_tokens=None,
                   sharded=sharded, mesh=mesh, shard_axes=shard_axes)
        if aux is not None:
            self._aux = aux
        return self

    # -- lazily-derived state ------------------------------------------------

    @property
    def idx(self) -> wtbc.WTBCIndex:
        """The single-host index (stacked per-shard index when sharded)."""
        return self._idx if self.backend == "single" else self._sharded.idx

    @property
    def aux(self) -> drb.DRBAux:
        """DRB tf bitmaps, built on first use (single backend)."""
        if self.backend != "single":
            return self._sharded.aux
        if self._aux is None:
            if not self.config.with_drb:
                raise ValueError("this engine was built with with_drb=False; "
                                 "DRB (and BM25) queries are unavailable")
            if self._doc_tokens is None:
                raise ValueError("DRB bitmaps unavailable: this engine was "
                                 "restored without them (snapshot.save builds "
                                 "them first when config.with_drb)")
            self._aux = drb.build_aux(self._idx, self.model, self._doc_tokens,
                                      eps=self.config.eps)
            self._doc_tokens = None     # raw tokens no longer needed
        return self._aux

    def _idf_table(self, measure) -> jnp.ndarray:
        """Per-measure idf table; on the sharded backend it is derived from
        the *global* document frequencies (a shard's local df would make
        shard scores incomparable)."""
        if measure.name not in self._idf_tables:
            if self.backend == "single":
                stats = self._idx
            else:
                stats = types.SimpleNamespace(
                    df=self._sharded.global_df,
                    n_docs=jnp.int32(self.n_docs))
            self._idf_tables[measure.name] = measure.idf(stats)
        return self._idf_tables[measure.name]

    @property
    def content_tag(self) -> int:
        """CRC32 fingerprint of what this engine would *answer with*: the
        config plus the index's document-frequency, separator-position and
        document-length tables.  Two engines with equal tags serve equal
        corpora under equal settings; the serving cache versions its keys
        with this so an ``swap_engine`` can never replay a stale hit — and a
        snapshot-restored engine naturally inherits the tag of the engine it
        was saved from (the arrays ARE the content)."""
        if self._content_tag is None:
            idx = self.idx
            h = zlib.crc32(repr(dataclasses.astuple(self.config)).encode())
            for leaf in (self._df_np, np.asarray(idx.sep_pos),
                         np.asarray(idx.doc_len)):
                h = zlib.crc32(np.ascontiguousarray(leaf), h)
            self._content_tag = h
        return self._content_tag

    def _avg_doc_len(self) -> jnp.ndarray:
        if self._avg_dl is None:
            idx = self._idx
            self._avg_dl = (jnp.sum(idx.doc_len.astype(jnp.float32))
                            / idx.n_docs.astype(jnp.float32))
        return self._avg_dl

    # -- query normalization -------------------------------------------------

    def _encode_queries(self, queries) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Word ids (array or ragged lists) -> padded (B, Q) frequency ranks
        + validity mask.  A single flat query becomes a batch of one.

        Q is padded up to a power-of-two bucket (extra columns masked out), so
        batches whose longest query differs only within a bucket share one
        compiled executor — the serving batcher coalesces mixed-length traffic
        relying on exactly this.  Masked columns are ignored by every backend
        (the invariant ragged queries already depend on), so bucketing never
        changes results."""
        if hasattr(queries, "ndim") or (
                len(queries) and np.isscalar(queries[0])):
            arr = np.asarray(queries, dtype=np.int64)
            if arr.ndim == 1:
                arr = arr[None, :]
            if arr.ndim != 2:
                raise ValueError(f"queries must be (B, Q) or (Q,), got shape "
                                 f"{arr.shape}")
            mask = np.ones(arr.shape, dtype=bool)
        else:
            rows = [np.asarray(q, dtype=np.int64).reshape(-1) for q in queries]
            if not rows:
                raise ValueError("empty query batch")
            Q = max((len(r) for r in rows), default=0)
            if Q == 0:
                raise ValueError("all queries are empty")
            arr = np.zeros((len(rows), Q), dtype=np.int64)
            mask = np.zeros((len(rows), Q), dtype=bool)
            for b, r in enumerate(rows):
                arr[b, :len(r)] = r
                mask[b, :len(r)] = True
        V = self.model.vocab_size
        bad = mask & ((arr < 1) | (arr >= V))
        if bad.any():
            raise ValueError(f"query word ids must be in [1, {V}); offending "
                             f"ids: {sorted(set(arr[bad].tolist()))[:10]}")
        Qb = pow2_bucket(arr.shape[1])
        if Qb != arr.shape[1]:
            arr = np.pad(arr, ((0, 0), (0, Qb - arr.shape[1])))
            mask = np.pad(mask, ((0, 0), (0, Qb - mask.shape[1])))
        ranks = np.where(mask, self.model.rank_of_word[arr], 0)
        return ranks.astype(np.int32), mask

    # -- dispatch ------------------------------------------------------------

    def _resolve_measure(self, measure):
        if isinstance(measure, str):
            try:
                return MEASURES[measure]
            except KeyError:
                raise ValueError(f"unknown measure {measure!r}; expected one "
                                 f"of {sorted(MEASURES)} or a scoring object")
        for attr in ("name", "dr_compatible", "idf", "score"):
            if not hasattr(measure, attr):
                raise ValueError(f"measure object lacks .{attr}")
        return measure

    def _resolve_strategy(self, strategy: str, measure, budget,
                          mode: str = "and") -> str:
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; expected one of "
                             f"{STRATEGIES}")
        if mode in POSITIONAL_MODES:
            # phrase/near run on the bare WTBC (locate/decode walks) — the
            # "no extra space" family; DRB bitmaps carry no positions.  Any
            # additive measure works: documents are fully materialized before
            # scoring, so DR's monotonicity restriction does not apply.
            if strategy == "drb":
                raise ValueError(f"mode={mode!r} runs on the bare WTBC; use "
                                 "strategy='dr' or 'auto'")
            if budget is not None:
                raise ValueError("budget (any-time max_pops) applies to the "
                                 "and/or DR strategy only")
            return "dr"
        if strategy == "auto":
            strategy = "dr" if measure.dr_compatible else "drb"
        if strategy == "dr":
            scoring.assert_dr_compatible(measure)   # BM25 + "dr" -> ValueError
        elif not self.config.with_drb:
            raise ValueError("this engine was built with with_drb=False; "
                             "only strategy='dr' is available")
        # DRB/AND honors budget (candidate-iteration cap, all-or-nothing
        # certification); the loop-free DRB/OR path normalizes it off
        # post-routing in search() — one serving profile carries the knob
        # across strategy routing without erroring on the exact paths.
        return strategy

    def _df_cap(self, ranks: np.ndarray, mask: np.ndarray) -> int:
        """DRB/OR gather width: max df among the query words (+2 slack),
        rounded up to a power of two so nearby workloads share one compiled
        executor instead of retracing per batch."""
        m = int(self._df_np[ranks[mask]].max()) if mask.any() else 1
        cap = 1 << int(m + 2 - 1).bit_length()
        return min(cap, self._max_df_cap)

    # -- anytime cost model (DESIGN.md §11) ----------------------------------

    def note_cost(self, seconds: float, pops_per_row: float) -> None:
        """Feed the live us/pop estimator one observed batch: ``seconds`` of
        blocking wall time against the mean per-row pop count (rows run
        vmapped in parallel, so the per-row count is what the wall clock
        tracks).  Called from the observed search path and from the serving
        dispatcher; EWMA so bursts move it quickly but one straggler does
        not poison the estimate."""
        if pops_per_row <= 0 or seconds <= 0:
            return
        us = seconds * 1e6 / float(pops_per_row)
        with self._stats_lock:
            prev = self._us_per_pop
            self._us_per_pop = us if prev is None else 0.8 * prev + 0.2 * us

    @property
    def us_per_pop(self) -> float:
        """Live cost estimate (µs of wall time per heap pop per row);
        ``DEFAULT_US_PER_POP`` until real traffic has been observed."""
        with self._stats_lock:
            est = self._us_per_pop
        return DEFAULT_US_PER_POP if est is None else est

    def budget_for_deadline(self, deadline_ms: float) -> int | None:
        """Pop budget affordable within ``deadline_ms`` at the live us/pop
        estimate, floor-quantized to a :func:`budget_bucket` so estimate
        drift never recompiles.  Returns None when the exhaustive search
        provably fits the deadline (a DR search pops < 2*n_docs + 2 segments
        — each split consumes one and adds at most two over < n_docs splits)
        — the caller then runs the plain exact executor, no key split."""
        pops = int(float(deadline_ms) * 1e3 / self.us_per_pop)
        if pops >= 2 * self.n_docs + 2:
            return None
        return budget_bucket(max(1, pops))

    @property
    def _obs(self) -> "obs.Registry":
        """The registry this engine records into: an explicitly adopted one
        (``obs_registry``, set by the serving frontend), else the *live*
        process default — looked up per call so ``obs.enable()``/``obs.use``
        after engine construction still take effect."""
        return self.obs_registry if self.obs_registry is not None \
            else obs.default_registry()

    def _executor(self, key: executors.ExecutorKey):
        with self._stats_lock:
            ex = self._executors.get(key)
        if ex is None:
            def note():
                with self._stats_lock:
                    self._trace_counts[key] = \
                        self._trace_counts.get(key, 0) + 1
                self._obs.counter(
                    "repro_engine_traces_total",
                    {"backend": key.backend, "strategy": key.strategy,
                     "mode": key.mode},
                    "executor jit traces (growth after warmup = key churn)",
                ).inc()
            if key.backend == "sharded":
                ex = executors.make_sharded(
                    key, mesh=self._mesh, shard_axes=self._shard_axes,
                    heap_cap=self._heap_cap, note=note)
            elif key.mode in POSITIONAL_MODES:
                ex = executors.make_single_positional(key, note=note)
            elif key.strategy == "dr":
                ex = executors.make_single_dr(key, heap_cap=self._heap_cap,
                                              mega_cap=self._mega_cap,
                                              note=note)
            else:
                ex = executors.make_single_drb(key, note=note)
            with self._stats_lock:
                ex = self._executors.setdefault(key, ex)
        return ex

    def suggested_df_cap(self, queries) -> int:
        """The DRB/OR gather width ``search`` would derive for ``queries`` —
        pass it back as ``search(..., df_cap=...)`` (or into a serving
        profile) to pin every batch drawn from the same word population onto
        one compiled executor regardless of which words each batch mixes."""
        ranks, mask = self._encode_queries(queries)
        return self._df_cap(ranks, mask)

    def warmup(self, queries, *, max_batch: int = 1, k: int | None = None,
               mode: str = "and", strategy: str = "auto", measure="tfidf",
               budget: int | None = None, sla: str | None = None,
               window: int | None = None,
               beam_width: int | None = None,
               df_cap: int | None = None,
               mega: bool | None = None) -> int:
        """Compile every executor the given traffic profile can hit before
        admitting traffic: one program per (batch bucket <= pow2(max_batch),
        Q bucket present in ``queries``).  Runs one real (tiny) search per
        shape, so after ``warmup`` steady-state traffic of this profile never
        retraces (``stats['traces']`` is the proof).  Returns the number of
        newly compiled executors.

        For ``strategy='drb', mode='or'`` pass an explicit ``df_cap``
        (e.g. :meth:`suggested_df_cap` of the serving word population) —
        otherwise the gather width is re-derived per batch and a heavier
        batch than any warmed one would still compile on the fly.
        """
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if hasattr(queries, "ndim") or (
                len(queries) and np.isscalar(queries[0])):
            arr = np.asarray(queries)
            rows = list(arr[None, :] if arr.ndim == 1 else arr)
        else:
            rows = [np.asarray(q).reshape(-1) for q in queries]
        reps = {}                       # Q bucket -> one representative row
        for r in rows:
            reps.setdefault(pow2_bucket(max(1, len(r))), r)
        before = sum(self._trace_counts.values())
        kw = dict(k=k, mode=mode, strategy=strategy, measure=measure,
                  budget=budget, sla=sla, window=window,
                  beam_width=beam_width, df_cap=df_cap, mega=mega)
        n_b = pow2_bucket(max_batch).bit_length()     # 1, 2, 4, ..., bucket
        for r in reps.values():
            row = [int(w) for w in r]
            for bb in (1 << i for i in range(n_b)):
                self.search([row] * bb, **kw)
        return sum(self._trace_counts.values()) - before

    def search(self, queries, *, k: int | None = None, mode: str = "and",
               strategy: str = "auto", measure="tfidf",
               budget: int | None = None,
               deadline_ms: float | None = None,
               sla: str | None = None,
               window: int | None = None,
               beam_width: int | None = None,
               df_cap: int | None = None,
               mega: bool | None = None) -> SearchResults:
        """Ranked top-k retrieval.

        queries:  (B, Q) / (Q,) array of word ids, or ragged lists of ids.
        k:        results per query (default: ``config.default_k``).
        mode:     "and" (conjunctive), "or" (bag-of-words), "phrase" (exact
                  consecutive in-order match), or "near" (all words within a
                  ``window``-token span).  phrase/near results additionally
                  carry match positions — see ``SearchResults.matches``.
        strategy: "dr" (no extra space), "drb" (tf bitmaps), or "auto" —
                  DR when the measure allows it, else DRB (e.g. BM25).
                  phrase/near always run on the bare WTBC ("dr").
        measure:  "tfidf", "bm25", or a scoring object.
        budget:   anytime work budget (per shard when sharded): DR heap pops /
                  DRB-AND candidate iterations; exact search when None.
                  Results carry per-slot ``certified`` bits and a
                  ``score_bound`` for whatever the budget cut off (DESIGN.md
                  §11); a budget that never binds is bitwise identical to
                  the exact search.  Normalized off on the loop-free DRB/OR
                  path; rejected on phrase/near (always exhaustive).
        deadline_ms: wall-clock target converted to a ``budget`` via the live
                  us/pop estimate (:meth:`budget_for_deadline`), quantized
                  to pow-4 buckets so estimate drift never recompiles.
                  Combines with an explicit ``budget`` by min.  Advisory,
                  not a hard timer — the loop bound is compiled in, the
                  engine never interrupts a running kernel.
        sla:      "exact", "bounded", or "best_effort" (default:
                  ``config.default_sla``, auto-promoted to "bounded" when
                  ``budget``/``deadline_ms`` is given).  "exact" *rejects*
                  anytime knobs — callers pinning sla="exact" can never be
                  silently degraded; "bounded" and "best_effort" differ only
                  in how the serving layer treats them under load (the
                  engine itself runs them identically).
        window:   proximity width in tokens, mode="near" only (default:
                  ``config.default_window``).  Traced — varying it reuses
                  the compiled executor.
        beam_width: frontier width P of the looped search cores (DR and/or,
                  DRB and; default ``config.default_beam_width``).  Each
                  iteration pops/verifies P candidates and batches their
                  rank workload into one fused call; P=1 is the classical
                  exact pop order, P>1 keeps results exact while cutting
                  loop trips ~P-fold (DESIGN.md §6).  Static, like ``k`` —
                  each distinct P compiles once and is cached.  Ignored
                  (normalized to 1) by the loop-free DRB/OR path; not
                  applicable to phrase/near.
        df_cap:   explicit DRB/OR gather width (static, pow2-bucketed and
                  clamped to the engine max).  By default the width is
                  derived from the batch's heaviest word, which makes the
                  executor key content-dependent — mixed traffic then
                  compiles one program per df bucket it happens to hit.
                  Serving pins this with :meth:`suggested_df_cap` so all
                  traffic shares one program.  Exactness-guarded: a cap
                  smaller than the batch actually needs raises instead of
                  silently truncating the gather.  DRB/OR only.
        mega:     run the batch on the pool-frontier megabatch core
                  (core/mega.py, DESIGN.md §8) instead of vmapping the
                  serial heap core (default ``config.default_mega``).
                  Row-for-row bitwise equal at the same Q bucket; the win
                  is throughput — per-row heap sifts under ``vmap`` lower
                  to whole-buffer scatters.  Applies to single-backend DR
                  and/or only and forces ``beam_width=1`` (the batch dim IS
                  the frontier parallelism); silently normalized off on
                  the paths it does not cover (DRB, positional, sharded),
                  so one serving profile can carry it across strategies.
        """
        k = self.config.default_k if k is None else int(k)
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
        if sla is not None and sla not in SLA_CLASSES:
            raise ValueError(f"unknown sla {sla!r}; expected one of "
                             f"{SLA_CLASSES}")
        if deadline_ms is not None and float(deadline_ms) <= 0:
            raise ValueError(f"deadline_ms must be positive, got {deadline_ms}")
        anytime = budget is not None or deadline_ms is not None
        sla = sla or ("bounded" if anytime else self.config.default_sla)
        if sla == "exact" and anytime:
            raise ValueError("sla='exact' guarantees an uninterrupted search "
                             "— budget/deadline_ms require sla='bounded' or "
                             "'best_effort'")
        if deadline_ms is not None:
            db = self.budget_for_deadline(deadline_ms)
            if db is not None:
                budget = db if budget is None else min(int(budget), db)
        if mode == "near":
            window = self.config.default_window if window is None else int(window)
            if window < 1:
                raise ValueError(f"window must be >= 1, got {window}")
        elif window is not None:
            raise ValueError(f"window applies to mode='near' only "
                             f"(got mode={mode!r})")
        m = self._resolve_measure(measure)
        if mode in POSITIONAL_MODES and deadline_ms is not None:
            raise ValueError("deadline_ms applies to the anytime and/or "
                             f"search cores only (got mode={mode!r}); "
                             "positional searches are always exhaustive")
        strat = self._resolve_strategy(strategy, m, budget, mode)
        if budget is not None:
            budget = int(budget)
            if budget < 1:
                raise ValueError(f"budget must be >= 1, got {budget}")
            if strat == "drb" and mode == "or":
                budget = None   # loop-free gather: always complete/certified
            elif budget >= 2 * self.n_docs + 2:
                budget = None   # can never bind: run the plain exact program
        if mode in POSITIONAL_MODES:
            if beam_width is not None:
                raise ValueError("beam_width applies to the looped and/or "
                                 f"search cores only (got mode={mode!r})")
            if self.backend == "sharded":
                raise ValueError(f"mode={mode!r} is not yet supported on the "
                                 "sharded backend; build a single-host engine")
            # positional top-k is a dense lax.top_k over the doc table
            k = min(k, self.n_docs)
        if beam_width is None:
            beam_width = self.config.default_beam_width
        elif int(beam_width) < 1:
            raise ValueError(f"beam_width must be >= 1, got {beam_width}")
        beam_width = int(beam_width)
        if mode in POSITIONAL_MODES or (strat == "drb" and mode == "or"):
            beam_width = 1          # no search loop: don't split the executor
        if mega is None:
            mega = self.config.default_mega
        # the mega core covers single-backend DR and/or; elsewhere normalize
        # it off (not an error: serving profiles carry one flag across
        # strategy routing) so executor keys never split spuriously
        mega = bool(mega) and (self.backend == "single" and strat == "dr"
                               and mode in ("and", "or"))
        if mega:
            beam_width = 1      # one pop per row: the batch dim IS the beam
        ranks, mask = self._encode_queries(queries)
        if strat == "drb" and mode == "or":
            auto_cap = self._df_cap(ranks, mask)
            if df_cap is None:
                df_cap = auto_cap
            else:
                df_cap = min(pow2_bucket(int(df_cap)), self._max_df_cap)
                if df_cap < auto_cap:
                    raise ValueError(
                        f"df_cap={df_cap} is smaller than the {auto_cap} this "
                        "batch's heaviest word needs — the gather would "
                        "silently truncate; pass a cap derived from "
                        "suggested_df_cap over the full word population")
        elif df_cap is not None:
            raise ValueError("df_cap applies to the DRB/OR gather path only "
                             f"(got strategy={strat!r}, mode={mode!r})")
        # resolve the descent-kernel lowering OUTSIDE the trace: the tag is
        # part of the executor key, so flipping a force/env (or an engine
        # built with another config.kernel_backend) compiles its own program
        # instead of replaying one lowered differently
        lowering = kernel_backend.descent_plan(self.config.kernel_backend
                                               if self.config.kernel_backend
                                               != "auto" else None).tag
        key = executors.ExecutorKey(self.backend, strat, mode, m, k,
                                    tuple(ranks.shape), budget, df_cap,
                                    beam_width, mega, lowering)
        ex = self._executor(key)
        words, wmask = jnp.asarray(ranks), jnp.asarray(mask)
        reg = self._obs
        t0 = time.perf_counter() if reg.enabled else 0.0
        match_pos = match_len = None
        if mode in POSITIONAL_MODES:
            res = ex(self.idx, words, wmask, self._idf_table(m),
                     jnp.int32(window or 0), self._avg_doc_len())
            match_pos, match_len = res.match_pos, res.match_len
        elif self.backend == "sharded":
            res = ex(self._sharded, words, wmask, self._idf_table(m))
        elif strat == "dr":
            res = ex(self.idx, words, wmask, self._idf_table(m))
        else:
            res = ex(self.idx, self.aux, words, wmask, self._idf_table(m),
                     self._avg_doc_len())
        if reg.enabled:
            self._record_search(reg, key, res, ranks.shape, t0)
        return SearchResults(docs=res.docs, scores=res.scores,
                             n_found=res.n_found, work=res.iters, k=k,
                             mode=mode, strategy=strat, measure=m.name,
                             match_pos=match_pos, match_len=match_len,
                             beam_width=beam_width,
                             pops=getattr(res, "pops", None),
                             overflowed=getattr(res, "overflowed", None),
                             padded=getattr(res, "padded", None),
                             certified=getattr(res, "certified", None),
                             score_bound=getattr(res, "bound", None),
                             sla=sla)

    def _record_search(self, reg: "obs.Registry", key, res, shape, t0):
        """Registry side of one observed search (enabled registries only):
        per-(backend, strategy, mode) dispatch counters, per-row work
        histograms (trips/pops/pad-waste), and the live WTBC query-roofline
        gauges.  Forces device completion first — the wall time must cover
        the compute, not just its dispatch — which is why the disabled path
        skips this method entirely (DESIGN.md §10 overhead budget)."""
        jax.block_until_ready(res.docs)
        dt = time.perf_counter() - t0
        B, Q = int(shape[0]), int(shape[1])
        labels = {"backend": key.backend, "strategy": key.strategy,
                  "mode": key.mode}
        reg.counter("repro_engine_searches_total", labels,
                    "search batches dispatched").inc()
        reg.counter("repro_engine_rows_total", labels,
                    "query rows searched").inc(B)
        reg.histogram("repro_engine_dispatch_seconds", labels,
                      "blocking wall time per search batch").observe(dt)
        reg.gauge("repro_engine_executors", None,
                  "compiled executors cached").set(len(self._executors))
        work = np.asarray(res.iters).ravel()
        reg.histogram("repro_engine_trips", labels,
                      "search-loop trips per query row"
                      ).observe_many(work.tolist())
        pops = getattr(res, "pops", None)
        padded = getattr(res, "padded", None)
        if pops is not None:
            pops = np.asarray(pops).ravel()
            reg.histogram("repro_engine_pops", labels,
                          "candidate pops per query row"
                          ).observe_many(pops.tolist())
            if key.budget is None and len(pops):
                # feed the deadline->budget estimator from *unbudgeted*
                # batches only: a budget-cut batch's wall time hides the
                # harvest tail and would bias us/pop optimistic
                self.note_cost(dt, float(pops.mean()))
            reg.gauge("repro_engine_us_per_pop", None,
                      "live pop cost estimate feeding deadline budgets"
                      ).set(self.us_per_pop)
        if padded is not None:
            padded = np.asarray(padded).ravel()
            reg.histogram("repro_engine_pad_lanes", labels,
                          "dead beam lanes per query row (pad waste)"
                          ).observe_many(padded.tolist())
        if pops is not None and len(pops):
            from repro.analysis import roofline
            rl = roofline.wtbc_query_roofline(
                backend=kernel_backend.canonical_backend(),
                measured_us_per_query=dt * 1e6 / max(B, 1),
                pops=float(pops.mean()),
                padded=float(padded.mean()) if padded is not None else 0.0,
                q=Q, block=int(self.config.block))
            roofline.live_wtbc_gauges(rl, reg)

    # -- post-processing -----------------------------------------------------

    def _local_index(self, doc: int):
        """Map a global doc id to (per-shard index pytree, local doc id).
        Shard slices are memoized — slicing the stacked pytree materializes a
        copy of every leaf, so pay it once per shard, not once per hit."""
        if self.backend == "single":
            return self._idx, doc
        base = np.asarray(self._sharded.doc_base)
        s = int(np.searchsorted(base, doc, side="right")) - 1
        if s not in self._shard_slices:
            self._shard_slices[s] = jax.tree.map(lambda x: x[s],
                                                 self._sharded.idx)
        return self._shard_slices[s], doc - base[s]

    def snippets(self, results: SearchResults,
                 length: int = 8) -> list[list[np.ndarray]]:
        """Decode the first ``length`` word ids of every hit document straight
        from the compressed index (no stored text).  Returns one list per
        query, one id array per hit (shorter docs come back whole)."""
        offs = jnp.arange(length, dtype=jnp.int32)
        out = []
        for b in range(len(results)):
            row = []
            for d, _score in results.hits(b):
                idx, local = self._local_index(d)
                n_take = min(length, int(np.asarray(idx.doc_len)[local]))
                lo = wtbc.doc_start(idx, jnp.int32(local))
                # fixed decode width (one compile per `length`, not per doc
                # length); positions clamped in-bounds, then trimmed on host
                ranks = np.asarray(jax.vmap(
                    lambda o: wtbc.decode_at(idx, jnp.minimum(lo + o, idx.n - 1))
                )(offs))[:n_take]
                row.append(np.asarray(self.model.word_of_rank)[ranks])
            out.append(row)
        return out

    def word_positions(self, doc: int, word_ids,
                       cap: int = 32) -> dict[int, np.ndarray]:
        """Doc-relative occurrence positions of each word id inside document
        ``doc`` (the first ``cap`` per word), extracted straight from the
        compressed index — the hit-highlighting companion to
        :meth:`snippets` (e.g. to mark every query word around a positional
        match)."""
        doc = int(doc)
        if not 0 <= doc < self.n_docs:
            raise ValueError(f"doc id {doc} outside [0, {self.n_docs})")
        idx, local = self._local_index(doc)
        V = self.model.vocab_size
        out = {}
        for w in word_ids:
            w = int(w)
            if not 1 <= w < V:
                raise ValueError(f"word id {w} outside [1, {V})")
            r = jnp.int32(self.model.rank_of_word[w])
            pos = np.asarray(positional.doc_positions(
                idx, r, jnp.int32(local), cap=cap))
            out[w] = pos[pos >= 0]
        return out

    # -- introspection -------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Executor-cache occupancy and per-key jit trace counts (snapshotted
        under the same lock ``note()`` mutates under, so a reader never sees
        a dict mid-resize)."""
        with self._stats_lock:
            return {"executors": len(self._executors),
                    "traces": dict(self._trace_counts)}

    def space_report(self) -> dict[str, int]:
        """Index (and built-DRB) space, bytes per component."""
        report = wtbc.space_report(self.idx)
        aux = self._aux if self.backend == "single" else self._sharded.aux
        if aux is not None:
            aux_rep = drb.space_report(aux)
            report.update({f"drb_{k}": v for k, v in aux_rep.items()})
            report["total"] += sum(aux_rep.values())
        return report


_CTOR_TOKEN = object()
