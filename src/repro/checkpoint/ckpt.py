"""Sharded checkpointing with crash-safe commits and elastic restore.

Layout (no external deps — npz shards + a JSON manifest):

    <dir>/step_000123/
        shard_00000.npz ... shard_NNNNN.npz   (one per host in a real job)
        MANIFEST.json                         (written LAST = commit point)

* Writes go to ``step_X.tmp/`` and are atomically renamed after the manifest
  (+ per-leaf CRC32s) is fsync'd — a crash mid-write can never yield a
  manifest-bearing but incomplete checkpoint; restore picks the newest
  directory that has a valid manifest.
* **Async**: `save_async` snapshots device arrays to host then hands the file
  I/O to a background thread; training continues immediately (the classic
  hide-the-checkpoint-behind-compute trick).
* **Elastic restore**: leaves are stored as full logical arrays; on restore
  they are re-sharded to whatever mesh/sharding the new job uses — resuming
  on a different device count is a pure re-slice (DESIGN.md §4).
"""
from __future__ import annotations

import json
import pathlib
import re
import threading
import zlib
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str | pathlib.Path, step: int, tree, *, fmt: str = "npz",
         meta: dict | None = None) -> pathlib.Path:
    """Synchronous crash-safe save of a pytree.

    fmt:  "npz" packs every leaf into one zipped archive (training default);
          "npy" writes one raw ``.npy`` per leaf, which ``restore`` can then
          memory-map — the zero-copy load path the serving snapshots use
          (a zip archive cannot be mmapped member-wise).
    meta: JSON-serializable caller metadata committed atomically with the
          arrays (``read_manifest`` returns it) — e.g. the serving snapshot's
          engine config + structural layout.
    """
    if fmt not in ("npz", "npy"):
        raise ValueError(f"unknown checkpoint format {fmt!r}")
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)

    named = _flatten_with_names(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, (_, l) in enumerate(named)}
    if fmt == "npz":
        np.savez(tmp / "shard_00000.npz", **arrays)
    else:
        for key, arr in arrays.items():
            np.save(tmp / f"{key}.npy", arr)
    manifest = {
        "step": step,
        "format": fmt,
        "leaves": [{"name": n, "key": key,
                    "shape": list(arrays[key].shape),
                    "dtype": str(arrays[key].dtype),
                    "crc32": zlib.crc32(
                        np.ascontiguousarray(arrays[key]).tobytes())}
                   for (n, _), key in zip(named, arrays)],
        "n_shards": 1,
        "user_meta": meta or {},
    }
    mpath = tmp / "MANIFEST.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)                     # atomic commit
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread; write on a daemon thread."""

    def __init__(self, ckpt_dir: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree):
        self.wait()                              # one outstanding write max
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now

        def work():
            save(self.dir, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.dir))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)


def list_steps(ckpt_dir: str | pathlib.Path) -> list[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "MANIFEST.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def read_manifest(ckpt_dir: str | pathlib.Path,
                  step: int | None = None) -> tuple[dict, int]:
    """The committed manifest (+ resolved step) without loading any arrays —
    snapshot loaders read the structural ``user_meta`` first to build the
    skeleton pytree ``restore`` fills in."""
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "MANIFEST.json").read_text()), step


def restore(ckpt_dir: str | pathlib.Path, tree_like, step: int | None = None,
            shardings=None, verify_crc: bool = True, mmap: bool = False):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings``: optional pytree of jax.sharding.Sharding — the elastic
    path: arrays are placed for the *current* mesh regardless of the mesh
    that wrote them.
    ``mmap``: memory-map leaves instead of reading them (``fmt="npy"``
    checkpoints only) — the returned arrays alias the files, so nothing is
    copied until a consumer touches (or device-puts) the pages.  Combine
    with ``verify_crc=False`` for a truly lazy load: CRC verification must
    fault in every page.
    """
    manifest, step = read_manifest(ckpt_dir, step)
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    fmt = manifest.get("format", "npz")
    if mmap and fmt != "npy":
        raise ValueError(f"mmap restore needs an fmt='npy' checkpoint, "
                         f"found {fmt!r}")
    if fmt == "npz":
        data = np.load(d / "shard_00000.npz")
        fetch = lambda key: data[key]
    else:
        fetch = lambda key: np.load(d / f"{key}.npy",
                                    mmap_mode="r" if mmap else None)

    names = [n for n, _ in _flatten_with_names(tree_like)]
    by_name = {l["name"]: l for l in manifest["leaves"]}
    leaves = []
    for n in names:
        meta = by_name[n]
        arr = fetch(meta["key"])
        if verify_crc:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption on leaf {n} "
                              f"(crc {crc} != {meta['crc32']})")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, step
