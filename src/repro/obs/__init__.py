"""repro.obs — unified metrics, per-request span tracing, and exporters.

The observability layer the serving stack, engine facade, kernel dispatcher
and roofline model all record into (DESIGN.md §10):

    metrics    Counter / Gauge / log2-sub-bucketed Histogram + Registry
               (disabled-by-default process registry; zero-cost when off)
    tracing    per-request Timeline (submit -> ... -> complete stage marks)
    export     Prometheus text format, JSONL snapshots, HTTP endpoint, dump()

Quick use::

    import repro.obs as obs
    obs.enable()                       # flip the process-default registry on
    ...serve traffic...
    print(obs.render_prometheus())     # or obs.dump() for plain data

Pure Python, no jax dependency — importable from anywhere in the stack
without cycles or device side effects.
"""
from repro.obs.export import (MetricsServer, dump, render_prometheus,
                              snapshot_line, write_jsonl)
from repro.obs.metrics import (SUBBUCKETS, Counter, Gauge, Histogram,
                               Registry, default_registry, enable, resolve,
                               use)
from repro.obs.tracing import STAGES, Timeline, stage_durations

__all__ = [
    "SUBBUCKETS", "STAGES", "Counter", "Gauge", "Histogram", "MetricsServer",
    "Registry", "Timeline", "default_registry", "dump", "enable",
    "render_prometheus", "resolve", "snapshot_line", "stage_durations",
    "use", "write_jsonl",
]
