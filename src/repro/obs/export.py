"""Exporters over :class:`repro.obs.Registry`: Prometheus text format,
JSONL snapshots, and a background HTTP scrape endpoint.

* :func:`render_prometheus` — the text exposition format (counters, gauges,
  and histograms with cumulative ``_bucket{le=...}`` series reconstructed
  from the log2 sub-buckets) — what ``launch/serve.py --metrics-port``
  serves at ``/metrics``.
* :func:`snapshot_line` / :func:`write_jsonl` — one JSON object per call
  (``{"ts": ..., "metrics": {...}}``), appendable to a log; the schema is
  exactly ``Registry.snapshot()`` (README §Observability documents it).
* :class:`MetricsServer` — a daemon-thread ``http.server`` serving
  ``/metrics`` (Prometheus) and ``/metrics.json`` (one snapshot object).
* :func:`dump` — the one-shot: snapshot the default registry, optionally
  append to a JSONL path, return the dict.
"""
from __future__ import annotations

import http.server
import json
import math
import threading
import time

from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               _label_str, bucket_hi, default_registry)


def _fmt(v: float) -> str:
    if v != v:                                   # NaN
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def render_prometheus(reg: Registry | None = None) -> str:
    """The registry in Prometheus text exposition format (version 0.0.4)."""
    reg = reg or default_registry()
    lines: list[str] = []
    seen_header: set[str] = set()
    for m in sorted(reg.metrics(), key=lambda m: (m.name, m.labels)):
        if m.name not in seen_header:
            seen_header.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
        ls = _label_str(m.labels)
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{m.name}{ls} {_fmt(m._snapshot())}")
            continue
        assert isinstance(m, Histogram)
        snap = m._snapshot()
        cum = snap["zeros"]
        if cum:
            lines.append(_bucket_line(m.name, m.labels, 0.0, cum))
        for idx, c in snap["buckets"].items():
            cum += c
            lines.append(_bucket_line(m.name, m.labels, bucket_hi(idx), cum))
        lines.append(_bucket_line(m.name, m.labels, math.inf, snap["count"]))
        lines.append(f"{m.name}_sum{ls} {_fmt(snap['sum'])}")
        lines.append(f"{m.name}_count{ls} {snap['count']}")
    return "\n".join(lines) + "\n"


def _bucket_line(name: str, labels: tuple, le: float, cum: int) -> str:
    items = labels + (("le", _fmt(le)),)
    inner = ",".join(f'{k}="{v}"' for k, v in items)
    return f"{name}_bucket{{{inner}}} {cum}"


def snapshot_line(reg: Registry | None = None) -> str:
    """One JSONL line: ``{"ts": unix-seconds, "metrics": snapshot}``."""
    reg = reg or default_registry()
    return json.dumps({"ts": time.time(), "metrics": reg.snapshot()},
                      sort_keys=True)


def write_jsonl(path, reg: Registry | None = None) -> None:
    with open(path, "a") as f:
        f.write(snapshot_line(reg) + "\n")


def dump(reg: Registry | None = None, path=None) -> dict:
    """One-shot: the default (or given) registry's snapshot as plain data;
    with ``path``, also append it as a JSONL line."""
    reg = reg or default_registry()
    if path is not None:
        write_jsonl(path, reg)
    return reg.snapshot()


class MetricsServer:
    """Background scrape endpoint: ``/metrics`` (Prometheus text) and
    ``/metrics.json`` (one snapshot object).  Daemon thread — never blocks
    shutdown; use as a context manager or call :meth:`close`."""

    def __init__(self, registry: Registry | None = None, port: int = 0,
                 host: str = "127.0.0.1"):
        reg = registry or default_registry()

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):                        # noqa: N802 (stdlib API)
                if self.path.startswith("/metrics.json"):
                    body = snapshot_line(reg).encode()
                    ctype = "application/json"
                elif self.path.startswith("/metrics"):
                    body = render_prometheus(reg).encode()
                    ctype = "text/plain; version=0.0.4"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                # quiet scrape logs
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True, name="obs-metrics-http")
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
