"""Unified metrics: counters, gauges, and log2-sub-bucketed histograms.

One :class:`Registry` per process (usually — :func:`default_registry`) holds
every metric family the serving stack, the engine facade, the kernel
dispatcher and the roofline attachment emit.  Design constraints, in order:

* **Disabled is free.**  The registry starts disabled; every recording
  method's first action is one attribute load + branch on
  ``self._reg.enabled`` — there is no locking, no allocation and no clock
  read on the disabled path, so production code leaves the instrumentation
  calls inline (DESIGN.md §10 pins the budget).
* **No raw-sample retention.**  Latency/work distributions are histograms:
  log2 major buckets split into ``SUBBUCKETS`` linear sub-buckets
  (HdrHistogram's scheme).  Percentile reconstruction returns the lower
  bound of the covering bucket, which makes it **exact for integer-valued
  observations below ``2 * SUBBUCKETS``** (work counters, batch sizes, pops
  — bucket width is <= 1 there) and bounds the relative error by
  ``1/SUBBUCKETS`` (6.25%) everywhere else.  Memory is O(occupied buckets),
  independent of the observation count.
* **Observation never perturbs results.**  Metrics are written from host
  Python after device values exist; nothing here feeds back into a traced
  computation (the exactness argument of DESIGN.md §10).

Thread-safety: every mutation takes the metric's own lock (submit threads
race the dispatch thread); reads (``snapshot``) copy under the same locks,
so a scrape can never observe a mid-mutation bucket dict.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Iterable

SUBBUCKETS = 16     # linear sub-buckets per log2 octave (rel. error 1/16)


def _label_key(labels: dict | None) -> tuple:
    return tuple(sorted((labels or {}).items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class _Metric:
    """Shared shell: name/labels/help plus the registry whose ``enabled``
    flag gates every write."""

    __slots__ = ("name", "labels", "help", "_reg", "_lock")

    def __init__(self, reg: "Registry", name: str, labels: tuple, help: str):
        self.name = name
        self.labels = labels
        self.help = help
        self._reg = reg
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonic event counter."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, reg, name, labels, help):
        super().__init__(reg, name, labels, help)
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self.value += n

    def _snapshot(self):
        with self._lock:
            return self.value


class Gauge(_Metric):
    """Last-write-wins instantaneous value (may go up or down)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, reg, name, labels, help):
        super().__init__(reg, name, labels, help)
        self.value = 0.0

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self.value = float(v)

    def _snapshot(self):
        with self._lock:
            return self.value


def bucket_index(v: float) -> int:
    """Index of the log2 sub-bucket covering ``v`` (> 0): octave ``e`` with
    ``v in [2^e, 2^(e+1))`` split into SUBBUCKETS linear slots."""
    m, e = math.frexp(v)                    # v = m * 2^e, m in [0.5, 1)
    sub = int((2.0 * m - 1.0) * SUBBUCKETS)  # 0 .. SUBBUCKETS-1
    if sub >= SUBBUCKETS:                    # fp edge: m == 1.0 - ulp
        sub = SUBBUCKETS - 1
    return (e - 1) * SUBBUCKETS + sub


def bucket_lo(idx: int) -> float:
    """Smallest value that lands in sub-bucket ``idx`` (its reconstruction
    representative — see the module docstring's exactness bound)."""
    e, sub = divmod(idx, SUBBUCKETS)
    return math.ldexp(1.0 + sub / SUBBUCKETS, e)


def bucket_hi(idx: int) -> float:
    """Exclusive upper bound of sub-bucket ``idx``."""
    e, sub = divmod(idx, SUBBUCKETS)
    return math.ldexp(1.0 + (sub + 1) / SUBBUCKETS, e)


class Histogram(_Metric):
    """Log2-sub-bucketed distribution with percentile reconstruction.

    Observations <= 0 land in a dedicated underflow bucket (reconstructed as
    0.0 — latencies and work counters are nonnegative, so the only mass there
    is genuine zeros).  ``quantile`` uses the nearest-rank definition over
    the bucket counts and returns the covering bucket's lower bound, except
    for the extremes where the tracked exact ``min``/``max`` are returned.
    """

    __slots__ = ("buckets", "n", "total", "vmin", "vmax", "n_zero")
    kind = "histogram"

    def __init__(self, reg, name, labels, help):
        super().__init__(reg, name, labels, help)
        self.buckets: dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.n_zero = 0

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        v = float(v)
        with self._lock:
            self.n += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if v <= 0.0:
                self.n_zero += 1
            else:
                i = bucket_index(v)
                self.buckets[i] = self.buckets.get(i, 0) + 1

    def observe_many(self, vs: Iterable[float]) -> None:
        if not self._reg.enabled:
            return
        for v in vs:
            self.observe(v)

    # -- reconstruction ------------------------------------------------------

    def quantile(self, q: float) -> float:
        """Nearest-rank percentile (``q`` in [0, 100]) reconstructed from the
        buckets; NaN when empty.  p0/p100 are the exact tracked extremes."""
        with self._lock:
            if self.n == 0:
                return math.nan
            if q <= 0:
                return self.vmin
            if q >= 100:
                return self.vmax
            rank = max(1, math.ceil(q / 100.0 * self.n))
            cum = self.n_zero
            if rank <= cum:
                return 0.0
            for i in sorted(self.buckets):
                cum += self.buckets[i]
                if rank <= cum:
                    return bucket_lo(i)
            return self.vmax

    def percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        return {f"p{q:g}": self.quantile(q) for q in qs}

    @property
    def mean(self) -> float:
        with self._lock:
            return self.total / self.n if self.n else math.nan

    def _snapshot(self):
        with self._lock:
            return {"count": self.n, "sum": self.total,
                    "min": self.vmin if self.n else None,
                    "max": self.vmax if self.n else None,
                    "zeros": self.n_zero,
                    "buckets": dict(sorted(self.buckets.items()))}


class Registry:
    """Get-or-create metric families keyed on ``(name, labels)``.

    ``enabled`` gates every write (see module docstring); metric objects can
    be created and held while disabled — they only start counting once the
    registry is enabled, so components bind their metrics at construction
    with no conditional wiring."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[tuple, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict | None, help: str):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(self, name, _label_key(labels), help)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, labels: dict | None = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, labels: dict | None = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, labels: dict | None = None,
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, labels, help)

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def find(self, name: str) -> list[_Metric]:
        """Every series of one metric family (any labels)."""
        with self._lock:
            return [m for (n, _), m in self._metrics.items() if n == name]

    def snapshot(self) -> dict:
        """Plain-data copy of every series: ``{name{labels}: value-or-hist}``
        — the JSONL exporter's payload.  Values are copied under each
        metric's own lock, never read live."""
        out = {}
        for m in self.metrics():
            out[m.name + _label_str(m.labels)] = m._snapshot()
        return out

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# process default
# ---------------------------------------------------------------------------

# Disabled by default: instrumentation must cost nothing unless asked for
# (launch/serve.py --metrics-port / --metrics enables it; tests use use()).
_DEFAULT = Registry(enabled=False)


def default_registry() -> Registry:
    return _DEFAULT


def resolve(reg: Registry | None) -> Registry:
    """The registry a component should record into: an explicit one, else
    the process default."""
    return reg if reg is not None else _DEFAULT


def enable(on: bool = True) -> Registry:
    """Turn the process-default registry on (or off); returns it."""
    _DEFAULT.enabled = on
    return _DEFAULT


@contextlib.contextmanager
def use(reg: Registry):
    """Swap the process-default registry for the dynamic extent of the
    context (tests/benchmarks isolate their metrics this way)."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, reg
    try:
        yield reg
    finally:
        _DEFAULT = prev
