"""Per-request span tracing: a timeline of named stage marks per ticket.

A :class:`Timeline` is a flat append-only list of ``(stage, t_monotonic)``
marks — no nesting, no context propagation: the serving pipeline is a fixed
linear sequence (DESIGN.md §10), so the span model can be this cheap.  The
canonical stages, in pipeline order::

    submit        client called SearchServer.submit
    admit         request validated, cache missed, entering the queue
    lane_enqueue  pulled off the admission queue into the batcher's deque
    batch_form    chosen into a coalesced batch
    dispatch      batch handed to the engine (t_dispatch)
    device        engine call returned and its device values are ready
    slice         per-row host slices materialized
    complete      ticket completed (t_complete)

Timelines are only allocated when the server's registry is enabled — a
disabled server leaves ``Ticket.timeline`` None and pays nothing.  The
derived stage *durations* the registry aggregates (queue-wait, device,
slice, total) are defined in :func:`stage_durations`; the raw marks survive
on the ticket for one-off debugging and the JSONL snapshot path.
"""
from __future__ import annotations

import time

STAGES = ("submit", "admit", "lane_enqueue", "batch_form", "dispatch",
          "device", "slice", "complete")


class Timeline:
    """Append-only ``(stage, t)`` marks for one request."""

    __slots__ = ("marks",)

    def __init__(self, t0: float | None = None):
        self.marks: list[tuple[str, float]] = \
            [("submit", time.monotonic() if t0 is None else t0)]

    def mark(self, stage: str, t: float | None = None) -> None:
        self.marks.append((stage, time.monotonic() if t is None else t))

    def t(self, stage: str) -> float | None:
        """First mark time of ``stage`` (None if never reached)."""
        for s, ts in self.marks:
            if s == stage:
                return ts
        return None

    def spans(self) -> list[tuple[str, float]]:
        """Consecutive-mark durations ``[(from->to, seconds), ...]`` in the
        order the request actually moved through the pipeline."""
        out = []
        for (s0, t0), (s1, t1) in zip(self.marks, self.marks[1:]):
            out.append((f"{s0}->{s1}", t1 - t0))
        return out

    def as_dict(self) -> dict[str, float]:
        """Stage -> first-mark time (for JSONL / debugging)."""
        out: dict[str, float] = {}
        for s, ts in self.marks:
            out.setdefault(s, ts)
        return out


def stage_durations(tl: Timeline) -> dict[str, float]:
    """The aggregated stage breakdown of one completed request.

    queue_wait  submit -> dispatch (admission + coalescing; for a cache hit,
                which never dispatches, 0)
    device      dispatch -> device (the engine call, device sync included)
    slice       device -> slice (host row materialization)
    total       submit -> complete

    Missing marks drop their stage from the dict rather than guessing.
    """
    ts = tl.as_dict()
    out: dict[str, float] = {}

    def span(name, a, b):
        if a in ts and b in ts:
            out[name] = ts[b] - ts[a]

    span("queue_wait", "submit", "dispatch")
    span("device", "dispatch", "device")
    span("slice", "device", "slice")
    span("total", "submit", "complete")
    return out
