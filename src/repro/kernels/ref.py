"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; the oracles
are also the CPU fallback paths used when kernels are disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitvec import WORDS_PER_BLOCK


def byte_rank_ref(data_padded: jnp.ndarray, counts: jnp.ndarray,
                  length: jnp.ndarray, bytes_q: jnp.ndarray,
                  pos_q: jnp.ndarray, *, block: int) -> jnp.ndarray:
    """vmap'd counter-gather + masked count (mirrors bytemap.rank)."""
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, length)

    def one(b, p):
        blk = p // block
        base = counts[blk, b]
        chunk = jax.lax.dynamic_slice_in_dim(data_padded, blk * block, block)
        mask = jnp.arange(block, dtype=jnp.int32) < (p - blk * block)
        return base + jnp.sum((chunk == b.astype(jnp.uint8)) & mask, dtype=jnp.int32)

    return jax.vmap(one)(bytes_q, pos_q)


def bitmap_rank1_ref(words: jnp.ndarray, counts: jnp.ndarray,
                     n_bits: jnp.ndarray, pos_q: jnp.ndarray) -> jnp.ndarray:
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, n_bits)

    def one(p):
        blk = p // (WORDS_PER_BLOCK * 32)
        chunk = jax.lax.dynamic_slice_in_dim(words, blk * WORDS_PER_BLOCK,
                                             WORDS_PER_BLOCK)
        n_valid = jnp.clip(p - blk * WORDS_PER_BLOCK * 32
                           - jnp.arange(WORDS_PER_BLOCK, dtype=jnp.int32) * 32, 0, 32)
        full = jnp.uint32(0xFFFFFFFF)
        mask = jnp.where(n_valid >= 32, full,
                         (jnp.uint32(1) << n_valid.astype(jnp.uint32)) - jnp.uint32(1))
        return counts[blk] + jnp.sum(
            jax.lax.population_count(chunk & mask).astype(jnp.int32))

    return jax.vmap(one)(pos_q)


def scored_topk_ref(cands: jnp.ndarray, query: jnp.ndarray, *, k: int
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    scores = cands.astype(jnp.float32) @ query.astype(jnp.float32)
    return jax.lax.top_k(scores, k)
