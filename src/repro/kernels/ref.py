"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; the oracles
are also the CPU fallback paths used when kernels are disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bytemap
from repro.core.bitvec import WORDS_PER_BLOCK


def byte_rank_ref(data_padded: jnp.ndarray, counts: jnp.ndarray,
                  length: jnp.ndarray, bytes_q: jnp.ndarray,
                  pos_q: jnp.ndarray, *, block: int) -> jnp.ndarray:
    """vmap'd counter-gather + masked count (mirrors bytemap.rank)."""
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, length)

    def one(b, p):
        blk = p // block
        base = counts[blk, b]
        chunk = jax.lax.dynamic_slice_in_dim(data_padded, blk * block, block)
        mask = jnp.arange(block, dtype=jnp.int32) < (p - blk * block)
        return base + jnp.sum((chunk == b.astype(jnp.uint8)) & mask, dtype=jnp.int32)

    return jax.vmap(one)(bytes_q, pos_q)


def bitmap_rank1_ref(words: jnp.ndarray, counts: jnp.ndarray,
                     n_bits: jnp.ndarray, pos_q: jnp.ndarray) -> jnp.ndarray:
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, n_bits)

    def one(p):
        blk = p // (WORDS_PER_BLOCK * 32)
        chunk = jax.lax.dynamic_slice_in_dim(words, blk * WORDS_PER_BLOCK,
                                             WORDS_PER_BLOCK)
        n_valid = jnp.clip(p - blk * WORDS_PER_BLOCK * 32
                           - jnp.arange(WORDS_PER_BLOCK, dtype=jnp.int32) * 32, 0, 32)
        full = jnp.uint32(0xFFFFFFFF)
        mask = jnp.where(n_valid >= 32, full,
                         (jnp.uint32(1) << n_valid.astype(jnp.uint32)) - jnp.uint32(1))
        return counts[blk] + jnp.sum(
            jax.lax.population_count(chunk & mask).astype(jnp.int32))

    return jax.vmap(one)(pos_q)


def scored_topk_ref(cands: jnp.ndarray, query: jnp.ndarray, *, k: int
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    scores = cands.astype(jnp.float32) @ query.astype(jnp.float32)
    return jax.lax.top_k(scores, k)


def wavelet_count_ref(levels, cw, cw_len, node_off, base_rank,
                      words, los, his) -> jnp.ndarray:
    """Batched 3-level count descent, pure jnp (mirrors wtbc.count_range).

    Same math as the ``wavelet_descent`` kernel: per level the 2·M endpoint
    ranks run as one vectorized batch (the level-to-level dependency is the
    only sequential part).  Oracle for the kernel and the vmap-safe CPU path.
    """
    words = words.astype(jnp.int32)
    M = words.shape[0]
    a = los.astype(jnp.int32)
    b = his.astype(jnp.int32)
    res = jnp.zeros((M,), jnp.int32)
    for L, lv in enumerate(levels):
        byte = cw[words, L]
        off = node_off[words, L]
        base = base_rank[words, L]
        pos = jnp.concatenate([off + a, off + b])            # (2M,)
        r = jax.vmap(lambda bb, pp: bytemap.rank(lv, bb, pp))(
            jnp.tile(byte, 2), pos)
        ra, rb = r[:M] - base, r[M:] - base
        is_leaf = cw_len[words] == (L + 1)
        res = jnp.where(is_leaf, rb - ra, res)
        a, b = ra, rb
    return res
