"""Pallas kernel: one whole device-resident beam iteration (DESIGN.md §9).

``core/mega.py`` runs Algorithm 1 with per-row pool frontiers; its while-loop
body is a host-orchestrated chain — lex-argmax extraction, a
``count_range_batch`` launch, scoring, two pool inserts — each a separate XLA
op over the full (B, cap) state.  This kernel fuses the ENTIRE trip into a
single launch with one grid step per batch row:

  pop      in-kernel lex-argmax over the row's (cap,) pool vectors (the same
           three masked reductions as ``heap.lex_argmax``), slot cleared in
           registers;
  emit     the popped singleton written straight to the row's output slot;
  descend  the Q-word × 3-level WTBC count of the left child, sharing
           ``wavelet_descent._descent_levels`` — the one descent definition —
           with Q-wide ``pl.load`` tile/counter gathers;
  score    an in-kernel (Q,)·(Q,) dot, unrolled round-each-product /
           add-left-to-right — the reduction ``einsum('bq,bq->b')`` compiles
           to (a fused ``jnp.dot`` FMA-contracts and drifts 1 ulp);
  push     two first-free-slot inserts, scalar scatters into the pool.

The frontier never round-trips: state arrays are input/output aliased, and a
trip writes only the touched cells (popped slot, ≤2 insert slots, the
emission slot, five per-row scalars) instead of materializing new (B, cap)
pools.  Gathers are Triton-style ``pl.load`` with computed flat indices, so
the lowering is GPU (or the Pallas interpreter — how CPU CI runs it); the TPU
path keeps the jnp mega body around the DMA-gather descent kernel.

Bitwise contract (pinned by tests/test_beam_fused.py): at matched
(B, Q, cap, k) this body is bit-for-bit ``mega.topk_dr_mega``'s — same pops,
same emissions, same overflow latching, including undersized-cap overflow
edges (cap stays EXACT; reductions run over pow2 lanes with padding masked,
never by growing cap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import heap as H
from repro.kernels import backend
from repro.kernels.wavelet_descent import (COUNTER_ROW, _descent_levels,
                                           _level_arrays, _tile_rank)

NEG_INF = -float("inf")
INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _at(vec, idx):
    """vec[idx] for a register vector and a traced scalar index (gather-free:
    a masked sum, exact because all other lanes contribute the identity)."""
    lane = jax.lax.iota(jnp.int32, vec.shape[0])
    zero = jnp.zeros((), vec.dtype)
    return jnp.sum(jnp.where(lane == idx, vec, zero))


def _kernel(words_ref, wmask_ref, idfw_ref,
            ps_in, p0_in, p1_in, ptf_in, od_in, os_in,
            no_in, it_in, pp_in, ov_in,
            sep_ref, nn_ref, len_ref, cwb_ref, cwl_ref, noff_ref, brank_ref,
            dA, cA, dB, cB, dC, cC,
            ps_out, p0_out, p1_out, ptf_out, od_out, os_out,
            no_out, it_out, pp_out, ov_out,
            *, Q: int, cap: int, k: int, conjunctive: bool,
            max_pops: int | None, block: int, n_blocks: tuple[int, ...]):
    i = pl.program_id(0)
    cap2 = _pow2(cap)
    lane = jax.lax.iota(jnp.int32, cap2)
    cmask = lane < cap
    qlane = jax.lax.iota(jnp.int32, Q)

    # ---- row state into registers (clamped loads + mask: always in-bounds).
    # ALL mutable-state reads go through the *_out refs: they alias the
    # inputs (pre-initialized), and — unlike the _in refs, which keep the
    # input snapshot in interpret mode — they observe this step's stores,
    # so read-after-write inside one trip is coherent.
    del ps_in, p0_in, p1_in, ptf_in, od_in, os_in, no_in, it_in, pp_in, ov_in
    cidx = i * cap + jnp.minimum(lane, cap - 1)
    s = jnp.where(cmask, pl.load(ps_out, (cidx,)), jnp.float32(NEG_INF))
    d0v = pl.load(p0_out, (cidx,))
    d1v = pl.load(p1_out, (cidx,))
    n_out = pl.load(no_out, (i,))
    iters = pl.load(it_out, (i,))
    pops = pl.load(pp_out, (i,))
    ov = pl.load(ov_out, (i,))

    active = (n_out < k) & jnp.any(s > NEG_INF)
    if max_pops is not None:
        active = active & (pops < max_pops)

    # ---- pop: heap.lex_argmax verbatim over the register pool
    valid = s > NEG_INF
    c = valid & (s == jnp.max(s))
    d0_ = jnp.where(c, d0v, INT32_MAX)
    c = c & (d0_ == jnp.min(d0_))
    j = jnp.argmax(jnp.where(c, d1v, INT32_MIN)).astype(jnp.int32)
    s_p = _at(s, j)
    d0 = _at(d0v, j)
    d1 = _at(d1v, j)
    tf = pl.load(ptf_out, (i * cap * Q + j * Q + qlane,))
    s = jnp.where((lane == j) & active, jnp.float32(NEG_INF), s)
    pl.store(ps_out, (i * cap + j,), _at(s, j))

    # ---- emit a popped singleton (slot k is the trash lane)
    single = active & ((d1 - d0) == 1)
    multi = active & ~single
    slot = jnp.where(single & (n_out < k), n_out, k)
    oidx = i * (k + 1) + slot
    pl.store(od_out, (oidx,), jnp.where(single, d0, pl.load(od_out, (oidx,))))
    pl.store(os_out, (oidx,), jnp.where(single, s_p, pl.load(os_out, (oidx,))))
    n_out = jnp.minimum(n_out + single.astype(jnp.int32), k)

    # ---- split: segment extents from sep_pos, then the fused Q-word descent
    n = nn_ref[0]
    n_docs = nn_ref[1]

    def doc_start(d):
        prev = pl.load(sep_ref, (jnp.maximum(d - 1, 0),))
        return jnp.where(d == 0, jnp.int32(0), prev + 1)

    mid = (d0 + d1) // 2
    lo1 = doc_start(d0)
    hi1 = jnp.where(mid >= n_docs, n, doc_start(mid))

    wq = pl.load(words_ref, (i * Q + qlane,))
    mq = pl.load(wmask_ref, (i * Q + qlane,))
    idfw = pl.load(idfw_ref, (i * Q + qlane,))
    cwb = [pl.load(cwb_ref, (wq * 3 + L,)) for L in range(3)]
    offq = [pl.load(noff_ref, (wq * 3 + L,)) for L in range(3)]
    baseq = [pl.load(brank_ref, (wq * 3 + L,)) for L in range(3)]
    cwl = pl.load(cwl_ref, (wq,))
    lens = [len_ref[L] for L in range(3)]
    data_refs = (dA, dB, dC)
    count_refs = (cA, cB, cC)
    blane = jax.lax.broadcasted_iota(jnp.int32, (Q, block), 1)

    def level_rank(L, byte, pa, pb):
        def rank1(p):
            blk = jnp.minimum(p // block, n_blocks[L] - 1)
            tile = pl.load(data_refs[L], (blk[:, None] * block + blane,))
            cnt = pl.load(count_refs[L], (blk * COUNTER_ROW + byte,))
            return cnt + _tile_rank(tile, byte, p, blk, block=block)
        return rank1(pa), rank1(pb)

    tf1 = _descent_levels(level_rank, cwb, offq, baseq, cwl,
                          jnp.full((Q,), 0, jnp.int32) + lo1,
                          jnp.full((Q,), 0, jnp.int32) + hi1, lens) * mq
    tf2 = tf - tf1

    # score: strict round-each-product, add-left-to-right — what the jnp
    # body's einsum('bq,bq->b') compiles to.  A plain jnp.dot here gets
    # FMA-contracted (extra-precision products), which drifts 1 ulp off the
    # einsum on some inputs and would break the bitwise contract; the lane
    # extraction is a masked sum (exact: other lanes add the identity).
    def row_dot(tfv):
        prod = tfv.astype(jnp.float32) * idfw
        acc = jnp.float32(0.0)
        for q in range(Q):
            acc = acc + jnp.sum(jnp.where(qlane == q, prod, jnp.float32(0.0)))
        return acc

    s1 = row_dot(tf1)
    s2 = row_dot(tf2)

    def seg_valid(tfv, sc):
        if conjunctive:
            return jnp.all((tfv > 0) | (mq == 0)) & jnp.any(mq != 0)
        return sc > 0.0

    # ---- push: two first-free-slot inserts (scalar scatters)
    def insert(s, d0v, d1v, ov, sc, da, db, tfv, enable):
        free = (s == NEG_INF) & cmask
        has_free = jnp.any(free)
        slot = jnp.argmax(free).astype(jnp.int32)
        ok = enable & has_free
        ov = ov | (enable & ~has_free).astype(jnp.int32)
        pidx = i * cap + slot
        pl.store(ps_out, (pidx,), jnp.where(ok, sc, _at(s, slot)))
        pl.store(p0_out, (pidx,), jnp.where(ok, da, _at(d0v, slot)))
        pl.store(p1_out, (pidx,), jnp.where(ok, db, _at(d1v, slot)))
        tidx = i * cap * Q + slot * Q + qlane
        pl.store(ptf_out, (tidx,),
                 jnp.where(ok, tfv, pl.load(ptf_out, (tidx,))))
        s = jnp.where((lane == slot) & ok, sc, s)
        d0v = jnp.where((lane == slot) & ok, da, d0v)
        d1v = jnp.where((lane == slot) & ok, db, d1v)
        return s, d0v, d1v, ov

    s, d0v, d1v, ov = insert(s, d0v, d1v, ov, s1, d0, mid, tf1,
                             multi & seg_valid(tf1, s1))
    s, d0v, d1v, ov = insert(s, d0v, d1v, ov, s2, mid, d1, tf2,
                             multi & seg_valid(tf2, s2))

    pl.store(no_out, (i,), n_out)
    pl.store(it_out, (i,), iters + active.astype(jnp.int32))
    pl.store(pp_out, (i,), pops + active.astype(jnp.int32))
    pl.store(ov_out, (i,), ov)


def fused_beam_step(idx, words, wmask, idf_w, pool, out_docs, out_scores,
                    n_out, iters, pops, overflowed, *, k: int,
                    conjunctive: bool, cap: int, max_pops: int | None,
                    interpret: bool):
    """Run ONE mega trip for every row in a single launch; returns the same
    state tuple shapes ``mega.topk_dr_mega``'s jnp body produces.  Call from
    inside the (jitted) mega while-loop — ``interpret`` must be resolved
    outside the trace (``backend.descent_plan``)."""
    B, Q = words.shape
    assert Q & (Q - 1) == 0, "fused beam step requires a pow2 Q bucket"
    block = idx.levels[0].block
    assert block & (block - 1) == 0, "fused beam step requires pow2 block"
    pool_s, pool_d0, pool_d1, pool_tf = pool
    tiles, counters, n_blocks = _level_arrays(idx.levels, block)
    flat = [t.reshape(-1) for t in tiles]
    cflat = [c.reshape(-1) for c in counters]
    nn = jnp.stack([jnp.int32(idx.n), jnp.int32(idx.n_docs)])
    lens = jnp.stack([jnp.int32(lv.length) for lv in idx.levels])
    sep = idx.sep_pos.astype(jnp.int32)
    if sep.shape[0] == 0:
        sep = jnp.zeros((1,), jnp.int32)

    state_in = (pool_s.reshape(-1), pool_d0.reshape(-1), pool_d1.reshape(-1),
                pool_tf.reshape(-1), out_docs.reshape(-1),
                out_scores.reshape(-1), n_out, iters, pops,
                overflowed.astype(jnp.int32))
    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in state_in]
    fn = pl.pallas_call(
        functools.partial(_kernel, Q=Q, cap=cap, k=k, conjunctive=conjunctive,
                          max_pops=max_pops, block=block, n_blocks=n_blocks),
        grid=(B,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 26,
        out_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 10,
        out_shape=out_shape,
        input_output_aliases={3 + t: t for t in range(10)},
        interpret=interpret,
    )
    (ps, p0, p1, ptf, od, os_, no, it, pp, ov) = fn(
        words.reshape(-1).astype(jnp.int32),
        wmask.reshape(-1).astype(jnp.int32),
        idf_w.reshape(-1).astype(jnp.float32),
        *state_in,
        sep, nn, lens,
        idx.cw.astype(jnp.int32).reshape(-1),
        idx.cw_len.astype(jnp.int32),
        idx.node_off.astype(jnp.int32).reshape(-1),
        idx.base_rank.astype(jnp.int32).reshape(-1),
        flat[0], cflat[0], flat[1], cflat[1], flat[2], cflat[2])
    cap_ = pool_s.shape[1]
    return ((ps.reshape(B, cap_), p0.reshape(B, cap_), p1.reshape(B, cap_),
             ptf.reshape(B, cap_, Q)),
            od.reshape(B, k + 1), os_.reshape(B, k + 1),
            no, it, pp, ov.astype(bool))


__all__ = ["fused_beam_step"]
_ = (H, backend)  # parity anchors: the kernel mirrors heap.lex_argmax
