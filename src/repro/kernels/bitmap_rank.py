"""Pallas TPU kernel: batched rank1 over packed tf bitmaps (WTBC-DRB).

DRB's triplet recomputation performs one ``rank1`` per query word per
candidate document; bag-of-words enumeration performs two ``select1`` per
document (each of which is block-search + one in-block rank).  The in-block
work is pure popcount: ``lax.population_count`` maps to the VPU.

Same scalar-prefetch pattern as ``byte_rank``: one grid step per query, the
(1, WORDS_PER_BLOCK) uint32 tile and the counter cell are DMA'd by index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitvec import WORDS_PER_BLOCK
from repro.kernels import backend

_SUPPORTED = ("tpu",)


def _kernel(blk_ref, pos_ref, words_ref, counts_ref, out_ref):
    i = pl.program_id(0)
    pos = pos_ref[i]
    start_bit = blk_ref[i] * (WORDS_PER_BLOCK * 32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, WORDS_PER_BLOCK), 1)
    n_valid = jnp.clip(pos - start_bit - lane * 32, 0, 32)
    w = words_ref[...]
    full = jnp.uint32(0xFFFFFFFF)
    mask = jnp.where(n_valid >= 32, full,
                     (jnp.uint32(1) << n_valid.astype(jnp.uint32)) - jnp.uint32(1))
    pc = jax.lax.population_count(w & mask).astype(jnp.int32)
    out_ref[0] = counts_ref[0] + jnp.sum(pc)


def bitmap_rank1(words: jnp.ndarray, counts: jnp.ndarray, n_bits: jnp.ndarray,
                 pos_q: jnp.ndarray, *,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Batched rank1: set bits among the first ``pos_q[i]`` bits.

    words: (n_words,) uint32 (padded to WORDS_PER_BLOCK multiple);
    counts: (n_blocks+1,) int32 cumulative ones;  pos_q: (B,).

    ``interpret`` defaults to compiled on TPU, interpret elsewhere.
    """
    return _bitmap_rank1(words, counts, n_bits, pos_q,
                         interpret=backend.resolve_interpret(interpret,
                                                             _SUPPORTED))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _bitmap_rank1(words, counts, n_bits, pos_q, *,
                  interpret: bool) -> jnp.ndarray:
    n_blocks = counts.shape[0] - 1
    tiles = words.reshape(n_blocks, WORDS_PER_BLOCK)
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, n_bits)
    blk = pos_q // (WORDS_PER_BLOCK * 32)
    B = pos_q.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # blk, pos
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, WORDS_PER_BLOCK), lambda i, blk, pos: (blk[i], 0)),
            pl.BlockSpec((1,), lambda i, blk, pos: (blk[i],)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, blk, pos: (i,)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )
    return fn(blk, pos_q, tiles, counts)
