"""Pallas TPU kernel: batched byte-rank over a counter-accelerated bytemap.

``rank_b(B, i)`` is the single hottest operation in the WTBC (every count /
locate / decode performs 2-6 of them; Algorithm 1 performs 2·Q per segment
split).  The TPU-native shape of the operation (DESIGN.md §2):

  rank_b(i) = counts[i // BLOCK, b]  +  popcount-style masked compare-reduce
              over the single BLOCK-byte tile containing position i

The kernel keeps that tile in VMEM and fuses the counter gather with the
residual reduce.  Data-dependent tile selection uses **scalar prefetch**: the
block index of every query is computed on the host side of the launch and fed
to the BlockSpec index_map, so the Pallas pipeline DMA-gathers exactly one
(1, BLOCK) tile of the byte array + one (1, 256) counter row per grid step.

Grid: one step per query (queries are the batch axis of serving).  The
compare-reduce over a 4-32KB tile is a handful of (8, 128) VPU ops; the DMA
is the cost, and it is the minimum possible traffic for an exact rank.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

_SUPPORTED = ("tpu",)


def _kernel(blk_ref, pos_ref, byte_ref, data_ref, counts_ref, out_ref, *, block: int):
    i = pl.program_id(0)
    pos = pos_ref[i]
    byte = byte_ref[i]
    base = counts_ref[0, byte]
    off = pos - blk_ref[i] * block               # in-tile residual cutoff
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    hits = (data_ref[...] == byte.astype(jnp.uint8)) & (lane < off)
    out_ref[0] = base + jnp.sum(hits.astype(jnp.int32))


def byte_rank(data_padded: jnp.ndarray, counts: jnp.ndarray, length: jnp.ndarray,
              bytes_q: jnp.ndarray, pos_q: jnp.ndarray, *, block: int,
              interpret: bool | None = None) -> jnp.ndarray:
    """Batched rank: occurrences of ``bytes_q[i]`` in ``data[: pos_q[i]]``.

    data_padded: (n_blocks*block,) uint8;  counts: (n_blocks+1, 256) int32
    cumulative;  bytes_q/pos_q: (B,).  Returns (B,) int32.

    ``interpret`` defaults to compiled on TPU, interpret elsewhere (this is a
    TPU-only lowering — resolved outside the jit trace).
    """
    return _byte_rank(data_padded, counts, length, bytes_q, pos_q, block=block,
                      interpret=backend.resolve_interpret(interpret, _SUPPORTED))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _byte_rank(data_padded, counts, length, bytes_q, pos_q, *, block: int,
               interpret: bool) -> jnp.ndarray:
    n_blocks = counts.shape[0] - 1
    tiles = data_padded.reshape(n_blocks, block)
    pos_q = jnp.clip(pos_q.astype(jnp.int32), 0, length)
    blk = pos_q // block
    B = pos_q.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                   # blk, pos, byte
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i, blk, pos, byte: (blk[i], 0)),
            pl.BlockSpec((1, 256), lambda i, blk, pos, byte: (blk[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, blk, pos, byte: (i,)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )
    return fn(blk, pos_q, bytes_q.astype(jnp.int32), tiles, counts)
