"""Pallas TPU kernel: fused 3-level WTBC count descent (DESIGN.md §6).

``count_range(w, lo, hi)`` — the inner operation of Algorithm 1 — performs two
``rank_b`` per wavelet-tree level.  Launched through ``byte_rank`` that is six
kernel launches per (word, range) triple, and the level-L positions depend on
the level-(L-1) rank results, so the launches cannot even overlap.  This
kernel fuses the whole root-to-leaf descent for a *batch* of M triples into a
single launch: one grid step per triple, and inside each step the three levels
run back-to-back out of VMEM.

Because the level-1/2 tile indices are data-dependent (they come from the
level-0/1 ranks computed *inside* the kernel), the usual scalar-prefetch
BlockSpec gather cannot feed them.  Instead the level byte arrays and counter
matrices stay in ``ANY`` memory space (HBM on TPU) and each rank issues a
manual ``pltpu.make_async_copy`` of exactly one (block,) byte tile and one
(256,) counter row into VMEM scratch — the same minimal traffic the BlockSpec
pipeline would DMA, just with in-kernel indices.  The two endpoint DMAs of a
level are started together and overlap.

Per grid step: 3 levels × 2 endpoints × (tile DMA + counter-row DMA + masked
compare-reduce).  The per-word node offsets / base ranks (scalar-prefetched)
keep it at 2 ranks per level exactly like the scalar path in
``wtbc.count_range``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bytemap import ByteMap

MAX_LEVELS = 3


def _kernel(cwb_ref, off_ref, base_ref, cwlen_ref, lo_ref, hi_ref, len_ref,
            d0, c0, d1, c1, d2, c2,
            out_ref, tile, row, tsem, rsem, *, block: int,
            n_blocks: tuple[int, ...]):
    i = pl.program_id(0)
    data_refs = (d0, d1, d2)
    count_refs = (c0, c1, c2)

    a = lo_ref[i]
    b = hi_ref[i]
    res = jnp.int32(0)
    for L in range(MAX_LEVELS):
        byte = cwb_ref[i, L]
        off = off_ref[i, L]
        base = base_ref[i, L]
        length = len_ref[L]
        pa = jnp.clip(off + a, 0, length)
        pb = jnp.clip(off + b, 0, length)
        # clamp the tile index into range; the residual cutoff then spans the
        # whole final tile, which is exactly rank(length) (counter row blk +
        # one full-tile count) — no special casing for pos == length
        blk_a = jnp.minimum(pa // block, n_blocks[L] - 1)
        blk_b = jnp.minimum(pb // block, n_blocks[L] - 1)
        copies = (
            pltpu.make_async_copy(data_refs[L].at[blk_a], tile.at[0], tsem.at[0]),
            pltpu.make_async_copy(data_refs[L].at[blk_b], tile.at[1], tsem.at[1]),
            pltpu.make_async_copy(count_refs[L].at[blk_a], row.at[0], rsem.at[0]),
            pltpu.make_async_copy(count_refs[L].at[blk_b], row.at[1], rsem.at[1]),
        )
        for cp in copies:
            cp.start()
        for cp in copies:
            cp.wait()
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
        hit_a = (tile[0:1, :] == byte.astype(jnp.uint8)) & (lane < pa - blk_a * block)
        hit_b = (tile[1:2, :] == byte.astype(jnp.uint8)) & (lane < pb - blk_b * block)
        ra = row[0, byte] + jnp.sum(hit_a.astype(jnp.int32)) - base
        rb = row[1, byte] + jnp.sum(hit_b.astype(jnp.int32)) - base
        is_leaf = cwlen_ref[i] == (L + 1)
        res = jnp.where(is_leaf, rb - ra, res)
        a, b = ra, rb
    out_ref[0] = res


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def wavelet_descent(levels: tuple[ByteMap, ...], cw: jnp.ndarray,
                    cw_len: jnp.ndarray, node_off: jnp.ndarray,
                    base_rank: jnp.ndarray, words: jnp.ndarray,
                    los: jnp.ndarray, his: jnp.ndarray, *, block: int,
                    interpret: bool = True) -> jnp.ndarray:
    """Batched fused count: occurrences of word-rank ``words[i]`` in the root
    range ``[los[i], his[i])``.  Returns (M,) int32.

    ``levels`` are the WTBC's per-level ByteMaps (uniform ``block``); ``cw`` /
    ``cw_len`` / ``node_off`` / ``base_rank`` the index's per-word tables.
    """
    M = words.shape[0]
    words = words.astype(jnp.int32)
    cwb = cw[words].astype(jnp.int32)                  # (M, 3) codeword bytes
    offs = node_off[words]                             # (M, 3)
    bases = base_rank[words]                           # (M, 3)
    cwl = cw_len[words]                                # (M,)
    lens = jnp.stack([lv.length for lv in levels])     # (3,)
    n_blocks = tuple(lv.counts.shape[0] - 1 for lv in levels)
    tiles = tuple(lv.data.reshape(n_blocks[L], block)
                  for L, lv in enumerate(levels))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,     # cwb, offs, bases, cwl, lo, hi, lens
        grid=(M,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 6,
        out_specs=pl.BlockSpec((1,), lambda i, *_: (i,)),
        scratch_shapes=[
            pltpu.VMEM((2, block), jnp.uint8),    # endpoint byte tiles
            pltpu.VMEM((2, 256), jnp.int32),      # endpoint counter rows
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, block=block, n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M,), jnp.int32),
        interpret=interpret,
    )
    return fn(cwb, offs, bases, cwl,
              los.astype(jnp.int32), his.astype(jnp.int32), lens,
              tiles[0], levels[0].counts,
              tiles[1], levels[1].counts,
              tiles[2], levels[2].counts)
