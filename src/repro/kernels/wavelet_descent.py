"""Pallas kernel family: fused 3-level WTBC count descent (DESIGN.md §6, §9).

``count_range(w, lo, hi)`` — the inner operation of Algorithm 1 — performs two
``rank_b`` per wavelet-tree level.  Launched through ``byte_rank`` that is six
kernel launches per (word, range) triple, and the level-L positions depend on
the level-(L-1) rank results, so the launches cannot even overlap.  The
kernels here fuse the whole root-to-leaf descent for a *batch* of M triples
into a single launch: one grid step per triple, and inside each step the three
levels run back-to-back.

Because the level-1/2 tile indices are data-dependent (they come from the
level-0/1 ranks computed *inside* the kernel), the usual scalar-prefetch
BlockSpec gather cannot feed them.  The two lowerings differ only in how the
in-kernel gather is expressed; the descent itself — range mapping, clipping,
leaf selection — is ONE shared definition (``_descent_levels``), so the TPU,
GPU and interpret paths cannot drift apart:

* **TPU** (``_kernel_tpu``): level byte arrays and counter matrices stay in
  ``ANY`` memory space (HBM) and each rank issues a manual
  ``pltpu.make_async_copy`` of exactly one (block,) byte tile and one (256,)
  counter row into VMEM scratch.  The two endpoint DMAs of a level start
  together and overlap.
* **GPU / Triton** (``_kernel_gpu``): the same gathers are in-kernel
  ``pl.load`` calls — a (2, block) integer-indexed gather of the endpoint
  tiles and two scalar counter loads — which Pallas lowers to Triton masked
  gather loads from global memory.  This is also the body the interpreter
  runs, so CPU-only CI exercises the Triton code path bit-for-bit.

Per grid step: 3 levels x 2 endpoints x (tile gather + counter gather +
masked compare-reduce).  The per-word node offsets / base ranks keep it at 2
ranks per level exactly like the scalar path in ``wtbc.count_range``.

Lowering selection (``kernels/backend.py``): compiled on real backends,
interpret only when explicitly requested or when no accelerator exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.pallas import triton as plgpu

from repro.core.bytemap import ByteMap
from repro.kernels import backend

MAX_LEVELS = 3
COUNTER_ROW = 256


def _tile_rank(tile, byte, pos, blk, *, block: int):
    """In-tile rank contribution: occurrences of ``byte`` in the ``blk``-th
    (block,) tile strictly before position ``pos``.  ``tile`` is (R, block)
    uint8; ``byte`` / ``pos`` / ``blk`` are (R,) int32.  Shared by every
    lowering — the single definition of the masked compare-reduce."""
    lane = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    hit = (tile == byte[:, None].astype(jnp.uint8)) \
        & (lane < (pos - blk * block)[:, None])
    return jnp.sum(hit.astype(jnp.int32), axis=1)


def _descent_levels(level_rank, cwb, off, base, cwl, a0, b0, lens):
    """The shared root-to-leaf descent: map the endpoint pair through the
    three levels, subtract the per-word base ranks, select the leaf's rank
    difference.  ``level_rank(L, byte, pa, pb) -> (ra, rb)`` supplies the
    lowering-specific gathered ranks (un-based); everything else — clipping,
    node offsets, leaf selection — is defined once here for TPU, GPU and
    interpret alike."""
    a, b = a0, b0
    res = jnp.int32(0)
    for L in range(MAX_LEVELS):
        byte = cwb[L]
        length = lens[L]
        pa = jnp.clip(off[L] + a, 0, length)
        pb = jnp.clip(off[L] + b, 0, length)
        # clamping the tile index into range makes the residual cutoff span
        # the whole final tile, which is exactly rank(length) (counter row
        # blk + one full-tile count) — no special casing for pos == length
        ra, rb = level_rank(L, byte, pa, pb)
        ra = ra - base[L]
        rb = rb - base[L]
        is_leaf = cwl == (L + 1)
        res = jnp.where(is_leaf, rb - ra, res)
        a, b = ra, rb
    return res


# ---------------------------------------------------------------------------
# TPU lowering: manual DMA tile gathers (ANY -> VMEM scratch)
# ---------------------------------------------------------------------------

def _kernel_tpu(cwb_ref, off_ref, base_ref, cwlen_ref, lo_ref, hi_ref, len_ref,
                d0, c0, d1, c1, d2, c2,
                out_ref, tile, row, tsem, rsem, *, block: int,
                n_blocks: tuple[int, ...]):
    i = pl.program_id(0)
    data_refs = (d0, d1, d2)
    count_refs = (c0, c1, c2)

    def level_rank(L, byte, pa, pb):
        blk_a = jnp.minimum(pa // block, n_blocks[L] - 1)
        blk_b = jnp.minimum(pb // block, n_blocks[L] - 1)
        copies = (
            pltpu.make_async_copy(data_refs[L].at[blk_a], tile.at[0], tsem.at[0]),
            pltpu.make_async_copy(data_refs[L].at[blk_b], tile.at[1], tsem.at[1]),
            pltpu.make_async_copy(count_refs[L].at[blk_a], row.at[0], rsem.at[0]),
            pltpu.make_async_copy(count_refs[L].at[blk_b], row.at[1], rsem.at[1]),
        )
        for cp in copies:
            cp.start()
        for cp in copies:
            cp.wait()
        intile = _tile_rank(tile[...], jnp.stack([byte, byte]),
                            jnp.stack([pa, pb]), jnp.stack([blk_a, blk_b]),
                            block=block)
        return row[0, byte] + intile[0], row[1, byte] + intile[1]

    out_ref[0] = _descent_levels(
        level_rank, cwb_ref[i], off_ref[i], base_ref[i], cwlen_ref[i],
        lo_ref[i], hi_ref[i], len_ref)


# ---------------------------------------------------------------------------
# GPU (Triton) lowering: in-kernel pl.load gathers from global memory
# ---------------------------------------------------------------------------

def _kernel_gpu(cwb_ref, off_ref, base_ref, cwlen_ref, lo_ref, hi_ref, len_ref,
                d0, c0, d1, c1, d2, c2,
                out_ref, *, block: int, n_blocks: tuple[int, ...]):
    i = pl.program_id(0)
    data_refs = (d0, d1, d2)
    count_refs = (c0, c1, c2)
    lane = jax.lax.broadcasted_iota(jnp.int32, (2, block), 1)

    def level_rank(L, byte, pa, pb):
        blk = jnp.stack([jnp.minimum(pa // block, n_blocks[L] - 1),
                         jnp.minimum(pb // block, n_blocks[L] - 1)])
        # endpoint tiles: one (2, block) integer-indexed gather — Triton
        # lowers this to masked gather loads from the flat byte stream
        tile = pl.load(data_refs[L], (blk[:, None] * block + lane,))
        # counter entries: the (blk, byte) cells of the flattened (blocks+1,
        # 256) counter matrix — two scalar loads, not a 256-wide row DMA
        cnt = pl.load(count_refs[L], (blk * COUNTER_ROW + byte,))
        intile = _tile_rank(tile, jnp.stack([byte, byte]),
                            jnp.stack([pa, pb]), blk, block=block)
        return cnt[0] + intile[0], cnt[1] + intile[1]

    out_ref[0] = _descent_levels(
        level_rank, cwb_ref[i], off_ref[i], base_ref[i], cwlen_ref[i],
        lo_ref[i], hi_ref[i], len_ref)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def _level_arrays(levels: tuple[ByteMap, ...], block: int):
    """Per-level (tiles, counters, n_blocks) with empty levels padded to one
    zero tile so in-kernel gathers stay in bounds on every lowering (an empty
    level is never the selected leaf of a real word; its clipped positions
    are 0, so the padded reads contribute base-cancelled zeros)."""
    tiles, counters, n_blocks = [], [], []
    for lv in levels:
        nb = lv.counts.shape[0] - 1
        if nb <= 0:
            tiles.append(jnp.zeros((1, block), jnp.uint8))
            counters.append(jnp.zeros((2, COUNTER_ROW), jnp.int32))
            n_blocks.append(1)
        else:
            tiles.append(lv.data.reshape(nb, block))
            counters.append(lv.counts)
            n_blocks.append(nb)
    return tiles, counters, tuple(n_blocks)


@functools.partial(jax.jit, static_argnames=("block", "kind", "interpret"))
def _descend(levels, cw, cw_len, node_off, base_rank, words, los, his, *,
             block: int, kind: str, interpret: bool) -> jnp.ndarray:
    M = words.shape[0]
    words = words.astype(jnp.int32)
    cwb = cw[words].astype(jnp.int32)                  # (M, 3) codeword bytes
    offs = node_off[words]                             # (M, 3)
    bases = base_rank[words]                           # (M, 3)
    cwl = cw_len[words]                                # (M,)
    lens = jnp.stack([lv.length for lv in levels])     # (3,)
    tiles, counters, n_blocks = _level_arrays(levels, block)

    if kind == "tpu":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=7,     # cwb, offs, bases, cwl, lo, hi, lens
            grid=(M,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 6,
            out_specs=pl.BlockSpec((1,), lambda i, *_: (i,)),
            scratch_shapes=[
                pltpu.VMEM((2, block), jnp.uint8),    # endpoint byte tiles
                pltpu.VMEM((2, COUNTER_ROW), jnp.int32),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        )
        fn = pl.pallas_call(
            functools.partial(_kernel_tpu, block=block, n_blocks=n_blocks),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((M,), jnp.int32),
            interpret=interpret,
        )
        return fn(cwb, offs, bases, cwl,
                  los.astype(jnp.int32), his.astype(jnp.int32), lens,
                  tiles[0], counters[0], tiles[1], counters[1],
                  tiles[2], counters[2])

    # gpu / Triton: flat streams, everything gathered in-kernel
    flat = [t.reshape(-1) for t in tiles]
    cflat = [c.reshape(-1) for c in counters]
    params = {} if interpret else {
        "compiler_params": plgpu.TritonCompilerParams(num_warps=4)}
    fn = pl.pallas_call(
        functools.partial(_kernel_gpu, block=block, n_blocks=n_blocks),
        grid=(M,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 13,
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((M,), jnp.int32),
        interpret=interpret,
        **params,
    )
    return fn(cwb, offs, bases, cwl,
              los.astype(jnp.int32), his.astype(jnp.int32), lens,
              flat[0], cflat[0], flat[1], cflat[1], flat[2], cflat[2])


def wavelet_descent(levels: tuple[ByteMap, ...], cw: jnp.ndarray,
                    cw_len: jnp.ndarray, node_off: jnp.ndarray,
                    base_rank: jnp.ndarray, words: jnp.ndarray,
                    los: jnp.ndarray, his: jnp.ndarray, *, block: int,
                    lowering: str | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Batched fused count: occurrences of word-rank ``words[i]`` in the root
    range ``[los[i], his[i])``.  Returns (M,) int32.

    ``levels`` are the WTBC's per-level ByteMaps (uniform ``block``); ``cw`` /
    ``cw_len`` / ``node_off`` / ``base_rank`` the index's per-word tables.

    ``lowering`` / ``interpret`` default to :func:`backend.kernel_plan` —
    compiled TPU or Triton kernel on a real accelerator, the portable Triton
    body under the interpreter otherwise.  Resolution happens here, outside
    the jit trace, so forced plans never leak into cached executables.
    """
    plan = backend.kernel_plan(lowering, interpret)
    return _descend(levels, cw, cw_len, node_off, base_rank, words, los, his,
                    block=block, kind=plan.kind, interpret=plan.interpret)
