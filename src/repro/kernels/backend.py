"""Kernel-lowering selection shared by every Pallas entry point.

Historically each kernel wrapper defaulted to ``interpret=True`` and
``kernels/ops.py`` hand-rolled an ``_on_tpu()`` check per call site — so a
direct kernel call on a real accelerator silently ran the Python interpreter
path unless the caller remembered to flip the flag.  This module centralizes
the policy (DESIGN.md §9):

* **interpret only when explicitly requested or when no real backend
  exists.**  ``resolve_interpret(None)`` is False exactly when
  ``jax.default_backend()`` is a platform the kernel has a lowering for.
* **descent dispatch** — ``descent_plan()`` picks the lowering of the fused
  wavelet-descent family: ``tpu`` (``make_async_copy`` tile gathers), ``gpu``
  (Pallas-on-Triton ``pl.load`` gathers), or ``ref`` (the vectorized pure-jnp
  fallback — strictly faster than sequential interpret-mode grids inside a
  search ``while_loop``, so it is the no-accelerator default).
* **forcing** — tests and the CI gpu-lowering job select a code path that the
  host cannot compile by forcing e.g. ``gpu:interpret`` (the Triton kernel
  body, run by the Pallas interpreter).  Either ``force_plan(...)`` (context
  manager) or the ``REPRO_KERNEL_BACKEND`` environment variable.

Resolution precedence: explicit argument > ``force_plan`` > environment >
auto-detection.  Resolution happens OUTSIDE jit traces (the plan strings are
static jit arguments), so a forced plan never leaks into a cached executable
compiled under a different plan.
"""
from __future__ import annotations

import contextlib
import os
from typing import NamedTuple

import jax

ENV_VAR = "REPRO_KERNEL_BACKEND"

# platforms with a real (compiled) lowering of the descent-family kernels
ACCELERATORS = ("tpu", "gpu")

_FORCED: list[str | None] = [None]


def canonical_backend() -> str:
    """``jax.default_backend()`` with vendor names collapsed: 'cuda'/'rocm'
    -> 'gpu'."""
    return {"cuda": "gpu", "rocm": "gpu"}.get(jax.default_backend(),
                                              jax.default_backend())


def accelerator() -> str | None:
    """'tpu' / 'gpu' when that is the default backend, else None."""
    b = canonical_backend()
    return b if b in ACCELERATORS else None


def resolve_interpret(interpret: bool | None,
                      supported: tuple[str, ...] = ACCELERATORS) -> bool:
    """The interpret flag a kernel entry point should run with.

    ``interpret`` not None is an explicit request and wins.  Otherwise
    interpret exactly when the default backend is not one the kernel has a
    compiled lowering for — the regression contract of ISSUE 8: a kernel
    called on a real backend must compile, not silently interpret."""
    if interpret is not None:
        return bool(interpret)
    return canonical_backend() not in supported


class KernelPlan(NamedTuple):
    """A resolved lowering choice for the descent-family kernels."""
    kind: str        # "tpu" | "gpu" | "ref"
    interpret: bool  # run the Pallas body under the interpreter

    @property
    def tag(self) -> str:
        """Canonical string form — the executor-cache key component."""
        return f"{self.kind}:interpret" if self.interpret else self.kind


VALID_REQUESTS = ("auto", "tpu", "gpu", "ref", "interpret",
                  "tpu:interpret", "gpu:interpret")


def _requested(request: str | None) -> str:
    req = request or _FORCED[0] or os.environ.get(ENV_VAR) or "auto"
    if req not in VALID_REQUESTS:
        raise ValueError(f"unknown kernel backend {req!r}; expected one of "
                         f"{VALID_REQUESTS}")
    return req


def descent_plan(request: str | None = None) -> KernelPlan:
    """Lowering for ``ops.wavelet_count_batch`` (and the fused beam-step).

    auto: tpu -> compiled TPU kernel, gpu -> compiled Triton kernel,
    else -> the vectorized jnp fallback (``ref``).  A forced accelerator kind
    the host cannot compile degrades to its interpret mode (that *is* the
    explicit request the interpret policy requires) — how CI exercises the
    Triton code path on CPU-only runners."""
    req = _requested(request)
    if req == "auto":
        acc = accelerator()
        plan = KernelPlan(acc, False) if acc else KernelPlan("ref", False)
    elif req == "ref":
        plan = KernelPlan("ref", False)
    elif req == "interpret":
        plan = KernelPlan("gpu", True)      # portable body under interpret
    else:
        kind, _, mode = req.partition(":")
        plan = KernelPlan(kind, mode == "interpret" or accelerator() != kind)
    _record_plan(plan)
    return plan


def _record_plan(plan: KernelPlan) -> None:
    """Count lowering resolutions per tag in the live obs registry — a
    production sanity gauge: a tag you didn't deploy showing up here means a
    stray force/env leaked into serving.  Free while the registry is
    disabled (the counter's write is one checked no-op)."""
    import repro.obs as obs
    reg = obs.default_registry()
    if not reg.enabled:              # skip even the get-or-create lookup
        return
    reg.counter("repro_kernel_plan_total", {"tag": plan.tag},
                "descent-kernel lowering resolutions by plan tag").inc()


def kernel_plan(lowering: str | None = None,
                interpret: bool | None = None) -> KernelPlan:
    """Like :func:`descent_plan` but for a direct kernel call, which cannot
    fall back to jnp: 'ref' (and the no-accelerator auto case) resolve to the
    portable gpu body under interpret."""
    plan = descent_plan(lowering)
    if plan.kind == "ref":
        plan = KernelPlan("gpu", True)
    if interpret is not None:
        plan = KernelPlan(plan.kind, bool(interpret))
    return plan


@contextlib.contextmanager
def force_plan(request: str):
    """Force a lowering for the dynamic extent of the context (tests/CI).
    Nested forces restore the previous value on exit."""
    _requested(request)                     # validate eagerly
    prev, _FORCED[0] = _FORCED[0], request
    try:
        yield
    finally:
        _FORCED[0] = prev
