"""Pallas TPU kernel: fused candidate scoring + blocked top-k.

Two consumers share this primitive:

* WTBC-DRB's final phase — "compute the relevance of all the candidates and
  then choose the best ones" (paper §5) — a top-k over a document-score table;
* the recsys ``retrieval_cand`` shape — score ONE query against 10^6
  candidate item embeddings and keep the k best (DESIGN.md §5: the same
  rank-a-large-candidate-set primitive).

Fusion matters because the naive path writes all C scores to HBM and reads
them back for top-k.  Here each grid step loads a (T, d) candidate tile into
VMEM, computes the tile's scores on the MXU (matvec), and reduces them to a
(k,) partial result in-register via k unrolled max/argmax extractions
(k <= 32 static; selection networks beat sorting for tiny k on the VPU).
HBM traffic: candidates read once, (n_tiles, k) written — no score spill.

A final (cheap) ``lax.top_k`` over the n_tiles*k partials runs outside the
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend

NEG = -3.0e38  # python float: jnp scalars may not be captured by kernel bodies


def _kernel(cands_ref, query_ref, out_s_ref, out_i_ref, *, k: int, tile: int):
    t = pl.program_id(0)
    scores = jnp.dot(cands_ref[...], query_ref[...].reshape(-1, 1),
                     preferred_element_type=jnp.float32).reshape(-1)  # (T,)
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile,), 0) + t * tile
    for j in range(k):                       # unrolled selection network
        m = jnp.max(scores)
        a = jnp.argmax(scores)
        out_s_ref[0, j] = m
        out_i_ref[0, j] = idx[a]
        scores = jnp.where(jax.lax.broadcasted_iota(jnp.int32, (tile,), 0) == a,
                           NEG, scores)


def scored_topk(cands: jnp.ndarray, query: jnp.ndarray, *, k: int,
                tile: int = 1024, interpret: bool | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k of ``cands @ query``: returns (scores (k,), indices (k,)).

    cands (C, d) float32/bf16 (C padded to a tile multiple by the caller or
    here), query (d,).  MXU-aligned choices: d multiple of 128, tile multiple
    of 8 (fp32) — asserted here to keep the claimed VMEM layout honest.

    ``interpret`` defaults to compiled on any real accelerator (the body is
    plain blocked Pallas — no TPU-specific primitives), interpret on CPU.
    """
    return _scored_topk(cands, query, k=k, tile=tile,
                        interpret=backend.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def _scored_topk(cands, query, *, k: int, tile: int,
                 interpret: bool) -> tuple[jnp.ndarray, jnp.ndarray]:
    C, d = cands.shape
    assert tile % 8 == 0, "sublane alignment"
    n_tiles = -(-C // tile)
    pad = n_tiles * tile - C
    if pad:
        cands = jnp.pad(cands, ((0, pad), (0, 0)))
    # padded rows must not win: give them -inf via a mask row appended to query?
    # cheaper: score pad rows are 0-dot = 0; shift all scores by nothing but
    # mask pad indices after the merge (indices >= C dropped below).

    fn = pl.pallas_call(
        functools.partial(_kernel, k=k, tile=tile),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda t: (t, 0)),
            pl.BlockSpec((d,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda t: (t, 0)),
            pl.BlockSpec((1, k), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )
    part_s, part_i = fn(cands.astype(jnp.float32), query.astype(jnp.float32))
    flat_s = part_s.reshape(-1)
    flat_i = part_i.reshape(-1)
    flat_s = jnp.where(flat_i < C, flat_s, NEG)   # drop padding rows
    top_s, pos = jax.lax.top_k(flat_s, k)
    return top_s, flat_i[pos]
