"""jit'd public wrappers for the Pallas kernels, with platform dispatch.

On TPU the ``pl.pallas_call`` path runs compiled; everywhere else (this CPU
container, unit tests) ``interpret=True`` executes the same kernel body in
Python for exact validation, or the pure-jnp oracle is used directly.

`use_kernels(False)` forces the oracle path (benchmark A/B switch).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro.core.bitvec import BitVec
from repro.core.bytemap import ByteMap
from repro.kernels import byte_rank as _byte_rank_k
from repro.kernels import bitmap_rank as _bitmap_rank_k
from repro.kernels import topk_score as _topk_score_k
from repro.kernels import wavelet_descent as _wavelet_descent_k
from repro.kernels import ref

_STATE = {"enabled": True}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@contextlib.contextmanager
def use_kernels(enabled: bool):
    prev = _STATE["enabled"]
    _STATE["enabled"] = enabled
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def rank_batch(bm: ByteMap, bytes_q: jnp.ndarray, pos_q: jnp.ndarray) -> jnp.ndarray:
    """Batched bytemap rank — kernel on TPU / interpret elsewhere."""
    if _STATE["enabled"]:
        return _byte_rank_k.byte_rank(bm.data, bm.counts, bm.length,
                                      bytes_q, pos_q, block=bm.block,
                                      interpret=not _on_tpu())
    return ref.byte_rank_ref(bm.data, bm.counts, bm.length, bytes_q, pos_q,
                             block=bm.block)


def bitmap_rank1_batch(bv: BitVec, pos_q: jnp.ndarray) -> jnp.ndarray:
    if _STATE["enabled"]:
        return _bitmap_rank_k.bitmap_rank1(bv.words, bv.counts, bv.n_bits,
                                           pos_q, interpret=not _on_tpu())
    return ref.bitmap_rank1_ref(bv.words, bv.counts, bv.n_bits, pos_q)


def scored_topk(cands: jnp.ndarray, query: jnp.ndarray, *, k: int,
                tile: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    if _STATE["enabled"]:
        return _topk_score_k.scored_topk(cands, query, k=k, tile=tile,
                                         interpret=not _on_tpu())
    return ref.scored_topk_ref(cands, query, k=k)


def wavelet_count_batch(levels, cw, cw_len, node_off, base_rank,
                        words, los, his) -> jnp.ndarray:
    """Batched fused 3-level WTBC count (the Algorithm-1 hot path).

    On TPU with kernels enabled this is ONE ``wavelet_descent`` launch for
    the whole (M × levels × 2) rank workload.  Elsewhere it is the pure-jnp
    batched descent (one vectorized rank batch per level): the interpret-mode
    kernel iterates its grid sequentially, which inside the beam search's
    ``while_loop`` is strictly slower than the vectorized oracle, so — unlike
    the standalone ops above — the non-TPU default is the oracle.  Kernel /
    oracle parity is pinned by tests/test_kernels.py, which runs the kernel
    in interpret mode explicitly.
    """
    if _STATE["enabled"] and _on_tpu():
        return _wavelet_descent_k.wavelet_descent(
            levels, cw, cw_len, node_off, base_rank, words, los, his,
            block=levels[0].block, interpret=False)
    return ref.wavelet_count_ref(levels, cw, cw_len, node_off, base_rank,
                                 words, los, his)


def segment_tf_batch(bm: ByteMap, byte, bounds) -> "jnp.ndarray":
    """Per-segment tf of one byte over sorted boundaries (kernel on TPU)."""
    from repro.kernels import segment_tf as _seg
    if _STATE["enabled"]:
        return _seg.segment_tf(bm.data, bm.counts, bm.length, byte, bounds,
                               block=bm.block, interpret=not _on_tpu())
    r = ref.byte_rank_ref(bm.data, bm.counts, bm.length,
                          jnp.full(bounds.shape, byte, jnp.int32),
                          bounds, block=bm.block)
    return r[1:] - r[:-1]
