"""Public wrappers for the Pallas kernels, with lowering dispatch.

Lowering policy lives in ``kernels/backend.py`` (DESIGN.md §9): each wrapper
asks for a plan and runs either a compiled kernel (TPU or Triton), the kernel
body under the Pallas interpreter (only when explicitly requested), or the
pure-jnp oracle.  ``descent_plan()`` governs the descent family — the
Algorithm-1 hot path — honouring ``force_plan`` / ``REPRO_KERNEL_BACKEND``;
the standalone TPU-only ops (byte_rank, bitmap_rank1, segment_tf) compile on
TPU and fall back to the oracle elsewhere (their scalar-prefetch pipelines
have no Triton lowering, and their sequential interpret-mode grids are
strictly slower than the vectorized oracle).

`use_kernels(False)` forces the oracle path everywhere (benchmark A/B
switch and the parity tests' reference arm).
"""
from __future__ import annotations

import contextlib

import jax.numpy as jnp

from repro.core.bitvec import BitVec
from repro.core.bytemap import ByteMap
from repro.kernels import backend
from repro.kernels import byte_rank as _byte_rank_k
from repro.kernels import bitmap_rank as _bitmap_rank_k
from repro.kernels import topk_score as _topk_score_k
from repro.kernels import wavelet_descent as _wavelet_descent_k
from repro.kernels import ref

_STATE = {"enabled": True}


@contextlib.contextmanager
def use_kernels(enabled: bool):
    prev = _STATE["enabled"]
    _STATE["enabled"] = enabled
    try:
        yield
    finally:
        _STATE["enabled"] = prev


def _standalone_kernel() -> bool:
    """Kernel-vs-oracle choice for the standalone TPU-only ops: compiled
    kernel on TPU, kernel under interpret only when a force/env explicitly
    asks for an interpret plan, oracle otherwise."""
    if not _STATE["enabled"]:
        return False
    plan = backend.descent_plan()
    if plan.kind == "tpu":
        return True
    return plan.interpret     # an explicit *:interpret request exercises them


def rank_batch(bm: ByteMap, bytes_q: jnp.ndarray, pos_q: jnp.ndarray) -> jnp.ndarray:
    """Batched bytemap rank — kernel on TPU / oracle elsewhere."""
    if _standalone_kernel():
        return _byte_rank_k.byte_rank(bm.data, bm.counts, bm.length,
                                      bytes_q, pos_q, block=bm.block)
    return ref.byte_rank_ref(bm.data, bm.counts, bm.length, bytes_q, pos_q,
                             block=bm.block)


def bitmap_rank1_batch(bv: BitVec, pos_q: jnp.ndarray) -> jnp.ndarray:
    if _standalone_kernel():
        return _bitmap_rank_k.bitmap_rank1(bv.words, bv.counts, bv.n_bits,
                                           pos_q)
    return ref.bitmap_rank1_ref(bv.words, bv.counts, bv.n_bits, pos_q)


def scored_topk(cands: jnp.ndarray, query: jnp.ndarray, *, k: int,
                tile: int = 1024) -> tuple[jnp.ndarray, jnp.ndarray]:
    if _standalone_kernel():
        return _topk_score_k.scored_topk(cands, query, k=k, tile=tile)
    return ref.scored_topk_ref(cands, query, k=k)


def wavelet_count_batch(levels, cw, cw_len, node_off, base_rank,
                        words, los, his) -> jnp.ndarray:
    """Batched fused 3-level WTBC count (the Algorithm-1 hot path).

    Dispatch via ``backend.descent_plan()``:

    * ``tpu`` / ``gpu`` — ONE ``wavelet_descent`` launch (DMA-gather or
      Triton ``pl.load``-gather lowering) for the whole (M × levels × 2)
      rank workload;
    * ``ref`` (no accelerator) — the pure-jnp batched descent, one
      vectorized rank batch per level.  The interpret-mode kernel iterates
      its grid sequentially, which inside the beam search's ``while_loop``
      is strictly slower than the vectorized oracle, so interpret runs only
      when a force/env explicitly asks for it (parity tests, the CI
      gpu-lowering job).
    """
    plan = backend.descent_plan() if _STATE["enabled"] else None
    if plan is not None and plan.kind in backend.ACCELERATORS:
        return _wavelet_descent_k.wavelet_descent(
            levels, cw, cw_len, node_off, base_rank, words, los, his,
            block=levels[0].block, lowering=plan.tag)
    return ref.wavelet_count_ref(levels, cw, cw_len, node_off, base_rank,
                                 words, los, his)


def segment_tf_batch(bm: ByteMap, byte, bounds) -> "jnp.ndarray":
    """Per-segment tf of one byte over sorted boundaries (kernel on TPU)."""
    from repro.kernels import segment_tf as _seg
    if _standalone_kernel():
        return _seg.segment_tf(bm.data, bm.counts, bm.length, byte, bounds,
                               block=bm.block)
    r = ref.byte_rank_ref(bm.data, bm.counts, bm.length,
                          jnp.full(bounds.shape, byte, jnp.int32),
                          bounds, block=bm.block)
    return r[1:] - r[:-1]
