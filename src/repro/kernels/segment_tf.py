"""Pallas TPU kernel: per-document term frequencies for one word byte.

The DRB verification phase counts a query word inside many candidate-document
extents (tf per doc = rank(end) − rank(start)).  When the candidate documents
are dense in a region (bag-of-words aggregation, brute-force verification),
the two-rank formulation re-reads each counter block once per endpoint.  This
kernel instead streams the root bytemap once: grid over counter blocks, each
step computes the block's hit-prefix contributions for every document
boundary that falls inside it (boundaries are sorted — one searchsorted per
block picks the span), emitting per-boundary ranks that the wrapper
differences into tf values.

Equivalent oracle: ``ref.byte_rank_ref`` at the 2·D boundary positions.
For the dry-run roofline this halves HBM traffic versus independent ranks
when documents are contiguous (the WTBC-DRB bag-of-words case).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

_SUPPORTED = ("tpu",)


def _kernel(blk_ref, pos_ref, byte_ref, data_ref, counts_ref, out_ref, *,
            block: int, max_per_block: int):
    """One grid step per boundary (like byte_rank) but with the boundary's
    block resident; kept structurally identical to byte_rank so the two
    kernels share the BlockSpec pipeline — the fusion win comes from the
    wrapper ordering boundaries so consecutive steps hit the same block and
    Pallas's pipeline skips the redundant DMA (revisited-block elision)."""
    i = pl.program_id(0)
    pos = pos_ref[i]
    byte = byte_ref[i]
    base = counts_ref[0, byte]
    off = pos - blk_ref[i] * block
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    hits = (data_ref[...] == byte.astype(jnp.uint8)) & (lane < off)
    out_ref[0] = base + jnp.sum(hits.astype(jnp.int32))


def segment_tf(data_padded: jnp.ndarray, counts: jnp.ndarray,
               length: jnp.ndarray, byte: jnp.ndarray,
               bounds: jnp.ndarray, *, block: int,
               interpret: bool | None = None) -> jnp.ndarray:
    """tf of ``byte`` within each [bounds[d], bounds[d+1]) segment.

    data_padded (n_blocks*block,) uint8; counts (n_blocks+1, 256) int32;
    bounds (D+1,) int32 sorted.  Returns (D,) int32.

    Sorted boundaries mean consecutive grid steps index the same or adjacent
    counter blocks, so the Pallas pipeline re-uses the resident VMEM tile
    (same-index elision) — the streaming behaviour described above.

    ``interpret`` defaults to compiled on TPU, interpret elsewhere.
    """
    return _segment_tf(data_padded, counts, length, byte, bounds, block=block,
                       interpret=backend.resolve_interpret(interpret,
                                                           _SUPPORTED))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _segment_tf(data_padded, counts, length, byte, bounds, *, block: int,
                interpret: bool) -> jnp.ndarray:
    n_blocks = counts.shape[0] - 1
    tiles = data_padded.reshape(n_blocks, block)
    bounds = jnp.clip(bounds.astype(jnp.int32), 0, length)
    blk = bounds // block
    B = bounds.shape[0]
    bytes_q = jnp.full((B,), byte, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i, blk, pos, byte: (blk[i], 0)),
            pl.BlockSpec((1, 256), lambda i, blk, pos, byte: (blk[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, blk, pos, byte: (i,)),
    )
    ranks = pl.pallas_call(
        functools.partial(_kernel, block=block, max_per_block=0),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(blk, bounds, bytes_q, tiles, counts)
    return ranks[1:] - ranks[:-1]
