"""Versioned on-disk snapshots of a built :class:`repro.engine.SearchEngine`.

The paper's system premise is that the compressed index IS the only thing
kept — so a server must be able to start from it directly instead of
re-deriving it from the raw corpus on every boot (which would both cost
minutes and require keeping the text the paper says we don't store).  A
snapshot persists everything a query needs:

    WTBCIndex (or the stacked ShardedWTBC)  — the compressed self-index
    DRBAux                                  — tf bitmaps, when built
    SCDCModel arrays                        — word-id <-> rank + codewords
    EngineConfig + structural metadata      — to reassemble the exact engine

Array payloads ride the crash-safe ``repro.checkpoint.ckpt`` machinery
(write-to-tmp, fsync'd manifest, atomic rename, per-leaf CRC32s) in its
``fmt="npy"`` layout: one raw ``.npy`` per leaf, so ``load`` memory-maps
them — the arrays alias the snapshot files and nothing is materialized until
first touch.  On the CPU backend even device placement is zero-copy:
``jax.device_put`` aliases the 64-byte-aligned mmap'd pages directly (see
``_device_put``), so a server boots in O(metadata), not O(index).  Structure
(tuple arities, static ``(s, c)``, per-level block sizes, backend) travels in
the manifest's ``user_meta``; ``load`` rebuilds a skeleton pytree from it and
lets ``ckpt.restore`` fill in the leaves by name.

    snapshot.save(engine, "snap/")            # -> version 1
    engine = snapshot.load("snap/")           # newest version, no corpus

Versions are monotonically increasing integers (one directory each), so a
serving fleet can roll forward/back by pointing at a version; ``save`` never
mutates a committed version in place.
"""
from __future__ import annotations

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import bitvec, bytemap, distributed, drb, scdc, wtbc
from repro.engine import EngineConfig
from repro.engine.facade import SearchEngine

SNAPSHOT_FORMAT = 1


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _structure_meta(engine: SearchEngine) -> dict:
    idx = engine.idx
    aux = engine._aux if engine.backend == "single" else engine._sharded.aux
    meta = {
        "snapshot_format": SNAPSHOT_FORMAT,
        "backend": engine.backend,
        "n_docs": int(engine.n_docs),
        "config": dataclasses.asdict(engine.config),
        "model": {"s": engine.model.s, "c": engine.model.c},
        "index": {"s": idx.s, "c": idx.c,
                  "blocks": [l.block for l in idx.levels],
                  "n_levels": len(idx.levels)},
        "has_aux": aux is not None,
        "aux_eps": None if aux is None else aux.eps,
    }
    if engine.backend == "sharded":
        ax = engine._shard_axes
        meta["n_shards"] = engine._sharded.n_shards
        meta["shard_axes"] = list(ax) if isinstance(ax, tuple) else ax
    return meta


def save(engine: SearchEngine, snap_dir: str | pathlib.Path,
         version: int | None = None) -> pathlib.Path:
    """Persist ``engine`` as a new snapshot version (committed atomically).

    A ``with_drb=True`` single-host engine gets its DRB bitmaps built first —
    the snapshot must be self-contained (no raw tokens survive a load, so a
    lazy build afterwards would be impossible).
    """
    snap_dir = pathlib.Path(snap_dir)
    if version is None:
        existing = ckpt.list_steps(snap_dir)
        version = (existing[-1] + 1) if existing else 1
    if engine.backend == "single":
        if engine.config.with_drb:
            engine.aux                        # force the lazy bitmap build
        state = {"idx": engine._idx, "aux": engine._aux,
                 "model": _model_arrays(engine.model)}
    else:
        state = {"sharded": engine._sharded,
                 "model": _model_arrays(engine.model)}
    return ckpt.save(snap_dir, version, state, fmt="npy",
                     meta=_structure_meta(engine))


def _model_arrays(model: scdc.SCDCModel) -> dict:
    return {"codes": model.codes, "lens": model.lens,
            "rank_of_word": model.rank_of_word,
            "word_of_rank": model.word_of_rank, "freqs": model.freqs}


# ---------------------------------------------------------------------------
# skeletons — correct treedef, dummy leaves; ckpt.restore swaps leaves by name
# ---------------------------------------------------------------------------

_Z = np.zeros(0)


def _skel_bytemap(block: int) -> bytemap.ByteMap:
    return bytemap.ByteMap(data=_Z, counts=_Z, length=_Z, block=block)


def _skel_index(meta: dict) -> wtbc.WTBCIndex:
    im = meta["index"]
    return wtbc.WTBCIndex(
        levels=tuple(_skel_bytemap(b) for b in im["blocks"]),
        offsets=tuple(_Z for _ in im["blocks"]),
        cw=_Z, cw_len=_Z, node_off=_Z, base_rank=_Z, sep_pos=_Z,
        df=_Z, occ=_Z, doc_len=_Z, n=_Z, n_docs=_Z,
        s=im["s"], c=im["c"])


def _skel_aux(meta: dict) -> drb.DRBAux | None:
    if not meta["has_aux"]:
        return None
    return drb.DRBAux(bv=bitvec.BitVec(words=_Z, counts=_Z, n_bits=_Z),
                      bit_off=_Z, has_bm=_Z, eps=meta["aux_eps"])


def _skel_state(meta: dict) -> dict:
    model = {k: _Z for k in ("codes", "lens", "rank_of_word",
                             "word_of_rank", "freqs")}
    if meta["backend"] == "single":
        return {"idx": _skel_index(meta), "aux": _skel_aux(meta),
                "model": model}
    return {"sharded": distributed.ShardedWTBC(
                idx=_skel_index(meta), aux=_skel_aux(meta),
                doc_base=_Z, global_df=_Z, global_idf=_Z, global_avg_dl=_Z,
                n_shards=meta["n_shards"]),
            "model": model}


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def list_versions(snap_dir: str | pathlib.Path) -> list[int]:
    """Committed snapshot versions, oldest first."""
    return ckpt.list_steps(snap_dir)


def load(snap_dir: str | pathlib.Path, version: int | None = None, *,
         verify: bool = True, mmap: bool = True,
         mesh=None) -> SearchEngine:
    """Reassemble a ready-to-query engine from a snapshot (newest version by
    default) — no corpus, no index build, no bitmap build.

    verify: CRC-check every leaf against the manifest (reads all pages; pass
            ``False`` for the lazy fastest start).
    mmap:   memory-map the arrays instead of reading them eagerly.
    mesh:   sharded snapshots only — the mesh to place shards on; defaults to
            a fresh 1-D mesh over the first ``n_shards`` local devices, like
            ``SearchEngine.shard`` builds.
    """
    manifest, version = ckpt.read_manifest(snap_dir, version)
    meta = manifest.get("user_meta") or {}
    fmt = meta.get("snapshot_format")
    if fmt != SNAPSHOT_FORMAT:
        raise ValueError(f"snapshot format {fmt!r} not supported "
                         f"(this build reads format {SNAPSHOT_FORMAT})")
    state, _ = ckpt.restore(snap_dir, _skel_state(meta), step=version,
                            verify_crc=verify, mmap=mmap)
    config = EngineConfig(**meta["config"])
    model = scdc.SCDCModel(s=meta["model"]["s"], c=meta["model"]["c"],
                           **state["model"])
    if meta["backend"] == "single":
        idx = _device_put(state["idx"])
        aux = _device_put(state["aux"]) if meta["has_aux"] else None
        return SearchEngine._restore(config=config, model=model,
                                     n_docs=meta["n_docs"], backend="single",
                                     idx=idx, aux=aux)
    sharded = _device_put(state["sharded"])
    axes = meta["shard_axes"]
    shard_axes = tuple(axes) if isinstance(axes, list) else axes
    if mesh is None:
        n_shards = meta["n_shards"]
        devices = jax.devices()
        if len(devices) < n_shards:
            raise ValueError(f"snapshot needs {n_shards} devices, have "
                             f"{len(devices)}; pass a mesh")
        names = shard_axes if isinstance(shard_axes, tuple) else (shard_axes,)
        if len(names) != 1:
            raise ValueError("multi-axis sharded snapshots need an explicit "
                             "mesh")
        mesh = jax.sharding.Mesh(
            np.array(devices[:n_shards]).reshape(n_shards), names)
    return SearchEngine._restore(config=config, model=model,
                                 n_docs=meta["n_docs"], backend="sharded",
                                 sharded=sharded, mesh=mesh,
                                 shard_axes=shard_axes)


def _device_put(tree):
    """Host arrays -> device arrays.

    On the CPU backend ``jax.device_put`` *aliases* host buffers that are
    64-byte aligned instead of copying — and ``.npy`` array payloads are
    64-byte aligned by format (header padding), so the mmap'd, read-only
    snapshot leaves become device arrays **zero-copy**: boot touches no
    data pages until a query faults them in (tests/test_mega.py pins the
    aliasing via ``unsafe_buffer_pointer``).  Dtype canonicalization
    (int64 -> int32 under the default x64 setting) matches ``jnp.asarray``
    exactly, so results are identical either way; non-CPU backends pay the
    one unavoidable host->device copy."""
    return jax.tree.map(jax.device_put, tree)
