"""repro.serve — the online serving subsystem over ``repro.engine``.

Layers (DESIGN.md §7):

    snapshot   versioned on-disk engine images; serve starts here, not from
               the raw corpus
    batcher    dynamic micro-batching onto power-of-two executor buckets
    cache      exact LRU result cache
    server     thread frontend: bounded queue -> batcher -> engine -> cache
    loadgen    closed/open-loop traffic + latency-percentile reports
"""
from repro.serve import loadgen, snapshot
from repro.serve.batcher import MicroBatcher, QueryProfile
from repro.serve.cache import LRUCache
from repro.serve.server import (DEFAULT_PROFILE, RowResult, SearchServer,
                                ShedError, Ticket)

__all__ = [
    "DEFAULT_PROFILE", "LRUCache", "MicroBatcher", "QueryProfile",
    "RowResult", "SearchServer", "ShedError", "Ticket", "loadgen", "snapshot",
]
