"""Thread-based serving frontend: admission queue -> micro-batcher -> engine
-> cache, with backpressure and per-request timing.

One dispatch thread owns the engine (executor dispatch is serialized, so jit
caches never race); submitters interact only with the bounded admission queue
and the result cache:

    server = SearchServer(engine, max_batch=16, max_wait_ms=2.0)
    server.warmup(example_queries)        # compile all bucket shapes first
    with server:
        row = server.search([w1, w2])     # blocking convenience
        t = server.submit([w1, w2])       # or async: ticket.result()

Backpressure / shed-load: the admission queue is bounded (``queue_depth``);
when it is full, ``submit`` raises :class:`ShedError` immediately instead of
queueing unbounded work — the caller (load balancer) retries elsewhere.  A
shed request costs microseconds, so an overloaded server stays responsive
for the traffic it *did* admit.

Exactness: identical to direct ``engine.search`` row-for-row (bitwise —
pinned in tests): batching only stacks rows, padding only adds dropped rows/
masked columns, and the cache only replays identical normalized requests.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

from repro.serve.batcher import Batch, MicroBatcher, QueryProfile
from repro.serve.cache import LRUCache

DEFAULT_PROFILE = QueryProfile()


class ShedError(RuntimeError):
    """Admission queue full — request rejected without queueing (shed load)."""


@dataclasses.dataclass
class RowResult:
    """One request's slice of a batched :class:`SearchResults` (host arrays).

    ``docs``/``scores`` are the (k,) ranked answer; ``n_found`` how many are
    real; diagnostics mirror ``SearchResults.diagnostics`` per row.
    """
    docs: np.ndarray
    scores: np.ndarray
    n_found: int
    work: int
    k: int
    mode: str
    strategy: str
    measure: str
    pops: int | None = None
    overflowed: bool | None = None
    match_pos: np.ndarray | None = None
    match_len: np.ndarray | None = None

    def hits(self) -> list[tuple[int, float]]:
        n = self.n_found
        return [(int(d), float(s))
                for d, s in zip(self.docs[:n], self.scores[:n])]


class Ticket:
    """Handle for one in-flight request: wait on :meth:`result`; timings are
    recorded by the server (``latency_s`` spans submit -> completion,
    queue wait included — the number a client actually experiences)."""

    __slots__ = ("words", "profile", "t_submit", "t_dispatch", "t_done",
                 "cache_hit", "batch_size", "_event", "_result", "_error")

    def __init__(self, words, profile):
        self.words = words
        self.profile = profile
        self.t_submit = time.monotonic()
        self.t_dispatch = None
        self.t_done = None
        self.cache_hit = False
        self.batch_size = 0
        self._event = threading.Event()
        self._result = None
        self._error = None

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def error(self) -> Exception | None:
        """The dispatch-time failure, if this request errored (load reports
        must not count errored tickets as served)."""
        return self._error

    def result(self, timeout: float | None = None) -> RowResult:
        if not self._event.wait(timeout):
            raise TimeoutError("request still in flight")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit

    def _complete(self, result=None, error=None):
        self._result, self._error = result, error
        self.t_done = time.monotonic()
        self._event.set()


class SearchServer:
    """Ties queue -> batcher -> engine -> cache together (one dispatch
    thread); collects the serving metrics the load harness reports."""

    def __init__(self, engine, *, max_batch: int = 16, max_wait_ms: float = 2.0,
                 queue_depth: int = 256, cache_size: int = 1024):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.engine = engine
        self.cache = LRUCache(cache_size)
        self._queue: queue.Queue = queue.Queue(maxsize=queue_depth)
        # pending_cap=queue_depth bounds admitted-but-undispatched work to
        # 2 x queue_depth (queue + batcher deque) under mixed-profile floods
        self._batcher = MicroBatcher(self._queue.get, max_batch=max_batch,
                                     max_wait_ms=max_wait_ms,
                                     pending_cap=queue_depth)
        self._thread: threading.Thread | None = None
        self._running = False
        self._lock = threading.Lock()
        self.n_submitted = 0
        self.n_served = 0
        self.n_shed = 0
        self.n_errors = 0
        self.n_overflowed = 0        # served rows whose heap latched overflow
        self.batch_hist: dict[int, int] = {}     # real batch size -> count
        self.dispatch_s = 0.0                    # engine wall time, summed

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "SearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="search-server-dispatch")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain everything already admitted, then stop the dispatch thread."""
        self._running = False
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    __enter__ = start

    def __exit__(self, *exc):
        self.stop()

    def warmup(self, example_queries, profile: QueryProfile = DEFAULT_PROFILE,
               ) -> int:
        """Precompile every (batch bucket, Q bucket) executor this server's
        coalescing can produce for ``profile`` — call before admitting
        traffic so no request ever pays a compile.  Returns the number of
        executors compiled."""
        return self.engine.warmup(example_queries,
                                  max_batch=self._batcher.max_batch,
                                  **profile.search_kwargs())

    # -- request path --------------------------------------------------------

    def _normalize(self, words, profile: QueryProfile) -> tuple[int, ...]:
        """Validate ONE query at admission.  Anything that could make
        ``engine.search`` reject a coalesced batch must be caught here — a
        poison row inside a batch would otherwise fail its innocent
        batch-mates."""
        arr = np.asarray(words, dtype=np.int64)
        if arr.ndim != 1:
            raise ValueError(f"submit takes one flat query, got shape "
                             f"{arr.shape}; submit batch rows individually "
                             "(coalescing is the server's job)")
        key = tuple(int(w) for w in arr)
        if not key:
            raise ValueError("empty query")
        V = self.engine.model.vocab_size
        bad = [w for w in key if not 1 <= w < V]
        if bad:
            raise ValueError(f"query word ids must be in [1, {V}); got {bad}")
        if profile.df_cap is not None:
            # reuse the facade's own cap formula (no drift) on the already-
            # validated ids — skipping suggested_df_cap's full re-encode
            # keeps the per-submit cost to one small fancy-index
            ranks = np.asarray(self.engine.model.rank_of_word)[list(key)]
            need = self.engine._df_cap(ranks[None, :],
                                       np.ones((1, len(key)), bool))
            if need > profile.df_cap:
                raise ValueError(
                    f"query needs df_cap {need} but this profile pins "
                    f"{profile.df_cap}; route it to a wider profile")
        return key

    def submit(self, words, profile: QueryProfile = DEFAULT_PROFILE) -> Ticket:
        """Admit one query; never blocks.  Cache hits complete immediately;
        a full admission queue raises :class:`ShedError`."""
        if self._thread is None:
            raise RuntimeError("server not started")
        key = self._normalize(words, profile)
        ticket = Ticket(key, profile)
        with self._lock:
            self.n_submitted += 1
        cached = self.cache.get((key, profile))
        if cached is not None:
            ticket.cache_hit = True
            ticket.batch_size = 1
            ticket._complete(result=cached)
            with self._lock:
                self.n_served += 1
            return ticket
        try:
            self._queue.put_nowait((key, profile, ticket, time.monotonic()))
        except queue.Full:
            with self._lock:
                self.n_shed += 1
            raise ShedError(f"admission queue full "
                            f"({self._queue.maxsize} deep); retry later")
        return ticket

    def search(self, words, profile: QueryProfile = DEFAULT_PROFILE,
               timeout: float | None = 60.0) -> RowResult:
        """Blocking submit -> result."""
        return self.submit(words, profile).result(timeout)

    # -- dispatch thread -----------------------------------------------------

    def _run(self):
        while self._running or not self._queue.empty() \
                or self._batcher._pending:
            batch = self._batcher.next_batch()
            if batch is not None:
                self._dispatch(batch)

    def _dispatch(self, batch: Batch):
        t0 = time.monotonic()
        for t in batch.items:
            t.t_dispatch = t0
            t.batch_size = batch.n_real
        try:
            res = self.engine.search(batch.queries,
                                     **batch.profile.search_kwargs())
        except Exception as e:                    # profile-level failure
            for t in batch.items:
                t._complete(error=e)
            with self._lock:
                self.n_errors += batch.n_real
            return
        dt = time.monotonic() - t0
        rows = _slice_rows(res, batch.n_real)
        n_over = 0
        for t, row in zip(batch.items, rows):
            self.cache.put((t.words, t.profile), row)
            t._complete(result=row)
            n_over += bool(row.overflowed)
        with self._lock:
            self.n_overflowed += n_over
            self.n_served += batch.n_real
            self.batch_hist[batch.n_real] = \
                self.batch_hist.get(batch.n_real, 0) + 1
            self.dispatch_s += dt

    # -- introspection -------------------------------------------------------

    @property
    def stats(self) -> dict:
        with self._lock:
            n_batches = sum(self.batch_hist.values())
            return {
                "submitted": self.n_submitted,
                "served": self.n_served,
                "shed": self.n_shed,
                "errors": self.n_errors,
                "overflowed": self.n_overflowed,
                "dispatches": n_batches,
                "batch_hist": dict(sorted(self.batch_hist.items())),
                "mean_batch": sum(b * c for b, c in self.batch_hist.items())
                              / n_batches if n_batches else 0.0,
                "dispatch_s": self.dispatch_s,
                "cache": self.cache.stats,
                "executors": self.engine.stats["executors"],
                "traces": sum(self.engine.stats["traces"].values()),
            }


def _slice_rows(res, n_real: int) -> list[RowResult]:
    """Split a batched SearchResults into per-request host rows (pad rows
    past ``n_real`` are dropped)."""
    docs = np.asarray(res.docs)
    scores = np.asarray(res.scores)
    n_found = np.asarray(res.n_found)
    work = np.asarray(res.work)
    pops = None if res.pops is None else np.asarray(res.pops)
    over = None if res.overflowed is None else np.asarray(res.overflowed)
    mp = None if res.match_pos is None else np.asarray(res.match_pos)
    ml = None if res.match_len is None else np.asarray(res.match_len)
    return [RowResult(
        docs=docs[b], scores=scores[b], n_found=int(n_found[b]),
        work=int(work[b]), k=res.k, mode=res.mode, strategy=res.strategy,
        measure=res.measure,
        pops=None if pops is None else int(pops[b]),
        overflowed=None if over is None else bool(over[b]),
        match_pos=None if mp is None else mp[b],
        match_len=None if ml is None else ml[b]) for b in range(n_real)]
