"""Thread-based serving frontend: admission queue -> micro-batcher -> engine
-> cache, with backpressure and per-request timing.

One dispatch thread owns the engine (executor dispatch is serialized, so jit
caches never race); submitters interact only with the bounded admission queue
and the result cache:

    server = SearchServer(engine, max_batch=16, max_wait_ms=2.0)
    server.warmup(example_queries)        # compile all bucket shapes first
    with server:
        row = server.search([w1, w2])     # blocking convenience
        t = server.submit([w1, w2])       # or async: ticket.result()

Backpressure / shed-load: the admission queue is bounded (``queue_depth``);
when it is full, ``submit`` raises :class:`ShedError` immediately instead of
queueing unbounded work — the caller (load balancer) retries elsewhere.  A
shed request costs microseconds, so an overloaded server stays responsive
for the traffic it *did* admit.

Exactness: identical to direct ``engine.search`` row-for-row (bitwise —
pinned in tests): batching only stacks rows, padding only adds dropped rows/
masked columns, and the cache only replays identical normalized requests —
under a key versioned by the engine's content tag, so replays can never
cross an :meth:`SearchServer.swap_engine` (drain -> swap -> clear).

Tail isolation (``work_buckets=True``): admission predicts per-query work
from summed word document frequencies and batches only within factor-8 work
lanes; predicted-heavy queries run alone (DESIGN.md §8).

Observability (DESIGN.md §10): every request carries a span
:class:`repro.obs.Timeline` (submit -> admit -> lane_enqueue -> batch_form
-> dispatch -> device -> slice -> complete) when the server's registry is
enabled, and the server mirrors its counters plus per-stage latency
histograms (queue-wait / device / slice / total) into that registry —
``stats`` remains the dict-shaped compatibility view, now built from
defensive snapshots so no reader can observe a mid-mutation engine or cache
dict.  With the registry disabled (the default) no timeline is allocated
and every recording call is a single checked no-op.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

import repro.obs as obs
from repro.engine.config import SLA_CLASSES
from repro.obs.tracing import Timeline, stage_durations
from repro.runtime.fault_tolerance import StragglerWatchdog
from repro.serve.batcher import (DEFAULT_LANE, Batch, Lane, MicroBatcher,
                                 QueryProfile, work_bucket)
from repro.serve.cache import LRUCache

DEFAULT_PROFILE = QueryProfile()

# degradation floor (DESIGN.md §11): the smallest anytime budget degraded
# serving will shrink to — below this a search returns so little that
# shedding is more honest than serving it
MIN_BUDGET = 8


class ShedError(RuntimeError):
    """Admission queue full — request rejected without queueing (shed load)."""


class RequestTimeout(TimeoutError):
    """A waiter gave up on a ticket and *finalized* it (:meth:`Ticket.cancel`)
    — distinct from ``ShedError`` (never admitted) and from dispatch errors
    (the engine failed); load reports bucket the three separately."""


@dataclasses.dataclass
class RowResult:
    """One request's slice of a batched :class:`SearchResults` (host arrays).

    ``docs``/``scores`` are the (k,) ranked answer; ``n_found`` how many are
    real; diagnostics mirror ``SearchResults.diagnostics`` per row.

    ``certified``/``score_bound``/``sla`` are the anytime contract
    (DESIGN.md §11): certified slots provably equal the exact oracle's;
    ``score_bound`` caps the score of everything not returned.
    """
    docs: np.ndarray
    scores: np.ndarray
    n_found: int
    work: int
    k: int
    mode: str
    strategy: str
    measure: str
    pops: int | None = None
    overflowed: bool | None = None
    padded: int | None = None
    match_pos: np.ndarray | None = None
    match_len: np.ndarray | None = None
    certified: np.ndarray | None = None
    score_bound: float | None = None
    sla: str = "exact"

    def hits(self) -> list[tuple[int, float]]:
        n = self.n_found
        return [(int(d), float(s))
                for d, s in zip(self.docs[:n], self.scores[:n])]

    @property
    def n_certified(self) -> int:
        """Certified result slots (== ``n_found`` when no data: exhaustive
        paths are exact end to end)."""
        if self.certified is None:
            return self.n_found
        return int(np.sum(self.certified[:self.n_found]))


class Ticket:
    """Handle for one in-flight request: wait on :meth:`result`; timings are
    recorded by the server (``latency_s`` spans submit -> completion,
    queue wait included — the number a client actually experiences; it
    decomposes exactly into :attr:`queue_wait_s` + :attr:`service_s`).
    ``timeline`` is the span trace (None unless the server's obs registry
    is enabled)."""

    __slots__ = ("words", "profile", "t_submit", "t_dispatch", "t_done",
                 "cache_hit", "batch_size", "timeline", "degraded",
                 "_event", "_result", "_error", "_lock")

    def __init__(self, words, profile):
        self.words = words
        self.profile = profile
        self.t_submit = time.monotonic()
        self.t_dispatch = None
        self.t_done = None
        self.cache_hit = False
        self.batch_size = 0
        self.degraded = False     # admission shrank the budget under load
        self.timeline: Timeline | None = None
        self._event = threading.Event()
        self._result = None
        self._error = None
        self._lock = threading.Lock()   # guards the complete/cancel race

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self, error: Exception) -> bool:
        """Resolve this ticket with ``error`` unless it already completed —
        the loadgen's timeout path (satellite of DESIGN.md §11): a timed-out
        ticket is *finalized*, never abandoned, so a late dispatch completion
        cannot resurrect it and leak into a later measurement window.
        Returns True if this call won the race."""
        with self._lock:
            if self._event.is_set():
                return False
            self._error = error
            self.t_done = time.monotonic()
            if self.timeline is not None:
                self.timeline.mark("complete", self.t_done)
            self._event.set()
            return True

    @property
    def error(self) -> Exception | None:
        """The dispatch-time failure, if this request errored (load reports
        must not count errored tickets as served)."""
        return self._error

    def result(self, timeout: float | None = None) -> RowResult:
        if not self._event.wait(timeout):
            raise TimeoutError("request still in flight")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def t_complete(self) -> float | None:
        """Completion time (alias of ``t_done`` — the span taxonomy's name
        for the terminal mark)."""
        return self.t_done

    @property
    def queue_wait_s(self) -> float | None:
        """Submit -> dispatch: admission backlog + coalescing wait.  0 for a
        cache hit (it never queues); None while in flight."""
        if self.t_done is None:
            return None
        if self.t_dispatch is None:
            return 0.0
        return self.t_dispatch - self.t_submit

    @property
    def service_s(self) -> float | None:
        """Dispatch -> complete: engine + host-slice time (for a cache hit,
        the full — microseconds-scale — completion time); None in flight."""
        if self.t_done is None:
            return None
        t0 = self.t_submit if self.t_dispatch is None else self.t_dispatch
        return self.t_done - t0

    def _complete(self, result=None, error=None):
        with self._lock:
            if self._event.is_set():      # lost the race to cancel()
                return
            self._result, self._error = result, error
            self.t_done = time.monotonic()
            if self.timeline is not None:
                self.timeline.mark("complete", self.t_done)
            self._event.set()


class SearchServer:
    """Ties queue -> batcher -> engine -> cache together (one dispatch
    thread); collects the serving metrics the load harness reports."""

    def __init__(self, engine, *, max_batch: int = 16, max_wait_ms: float = 2.0,
                 queue_depth: int = 256, cache_size: int = 1024,
                 work_buckets: bool = False, heavy_df: int | None = None,
                 adaptive_wait: bool = False,
                 registry: "obs.Registry | None" = None):
        """``work_buckets`` turns on df-predicted admission lanes: queries
        coalesce only within a factor-8 bucket of their summed word document
        frequency, and queries at or past ``heavy_df`` (default: twice the
        engine's document count) run at batch size 1 so they never tax
        lighter batch-mates (DESIGN.md §8).  ``adaptive_wait`` collapses the
        coalescing wait to 0 while the arrival stream is idle.  ``registry``
        is the :mod:`repro.obs` registry counters/histograms/span timelines
        record into (default: the process registry, disabled unless
        ``obs.enable()``/the CLI metrics flags turned it on); the engine is
        pinned to the same registry (``engine.obs_registry``) so engine-side
        counters land next to the serving ones."""
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.engine = engine
        self.obs = obs.resolve(registry)
        if hasattr(engine, "obs_registry"):
            engine.obs_registry = self.obs       # engine records where we do
        self.cache = LRUCache(cache_size, registry=self.obs)
        self.work_buckets = work_buckets
        self._heavy_df_explicit = heavy_df is not None
        self.heavy_df = heavy_df if heavy_df is not None else \
            2 * int(getattr(engine, "n_docs", 1 << 29))
        # engine content tag versions every cache key: a swapped-in engine
        # can never satisfy a hit stored under its predecessor
        self._tag = getattr(engine, "content_tag", None)
        self._queue: queue.Queue = queue.Queue(maxsize=queue_depth)
        # pending_cap=queue_depth bounds admitted-but-undispatched work to
        # 2 x queue_depth (queue + batcher deque) under mixed-profile floods
        self._batcher = MicroBatcher(self._queue.get, max_batch=max_batch,
                                     max_wait_ms=max_wait_ms,
                                     pending_cap=queue_depth,
                                     adaptive_wait=adaptive_wait,
                                     registry=self.obs)
        self._thread: threading.Thread | None = None
        self._running = False
        self._draining = False       # swap in progress: shed new admissions
        self._n_inflight = 0         # admitted, not yet completed/errored
        self._lock = threading.Lock()
        # degraded serving engages when the admission backlog crosses this
        # (DESIGN.md §11): non-exact traffic gets its budget shrunk so the
        # queue drains instead of growing into the shed wall
        self._degrade_at = max(1, (3 * queue_depth) // 4)
        self._watchdog = StragglerWatchdog()     # dispatch-batch step times
        self._step = 0                           # watchdog step counter
        self.n_submitted = 0
        self.n_served = 0
        self.n_shed = 0
        self.n_degraded = 0
        self.n_stragglers = 0
        self.n_errors = 0
        self.n_swaps = 0
        self.n_overflowed = 0        # served rows whose heap latched overflow
        self.n_padded = 0            # summed pad-waste lanes of served rows
        self.batch_hist: dict[int, int] = {}     # real batch size -> count
        self.dispatch_s = 0.0                    # engine wall time, summed
        # registry mirrors of the counters above + the stage histograms
        req = "repro_server_requests_total"
        self._m_req = {o: self.obs.counter(req, {"outcome": o},
                                           "requests by terminal outcome")
                       for o in ("submitted", "served", "shed", "error",
                                 "cache_hit", "degraded")}
        self._m_straggler = self.obs.counter(
            "repro_server_straggler_batches_total", None,
            "dispatch batches the step-time watchdog flagged slow")
        self._m_swaps = self.obs.counter("repro_server_swaps_total", None,
                                         "engine hot-swaps completed")
        self._m_overflow = self.obs.counter(
            "repro_server_overflow_rows_total", None,
            "served rows whose search heap latched overflow")
        self._m_padded = self.obs.counter(
            "repro_server_padded_lanes_total", None,
            "dead beam lanes paid for by served rows (pad waste)")
        self._m_dispatch = self.obs.histogram(
            "repro_dispatch_seconds", None, "engine wall time per batch")
        self._m_stage = {s: self.obs.histogram(
            "repro_request_stage_seconds", {"stage": s},
            "per-request latency by pipeline stage")
            for s in ("queue_wait", "device", "slice", "total")}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "SearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._running = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="search-server-dispatch")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain everything already admitted, then stop the dispatch thread."""
        self._running = False
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    __enter__ = start

    def __exit__(self, *exc):
        self.stop()

    def warmup(self, example_queries, profile: QueryProfile = DEFAULT_PROFILE,
               ) -> int:
        """Precompile every (batch bucket, Q bucket) executor this server's
        coalescing can produce for ``profile`` — call before admitting
        traffic so no request ever pays a compile.  Also precompiles the
        *effective* profile admission would resolve this one into
        (DESIGN.md §11: a ``deadline_ms`` becomes a concrete pop budget at
        submit), so a deadline-carrying profile doesn't pay its compile on
        the first real request.  Returns the number of executors compiled."""
        n = self.engine.warmup(example_queries,
                               max_batch=self._batcher.max_batch,
                               **profile.search_kwargs())
        eff, _ = self._effective(profile, None)
        if eff != profile:
            n += self.engine.warmup(example_queries,
                                    max_batch=self._batcher.max_batch,
                                    **eff.search_kwargs())
        return n

    # -- request path --------------------------------------------------------

    def _normalize(self, words, profile: QueryProfile) -> tuple[int, ...]:
        """Validate ONE query at admission.  Anything that could make
        ``engine.search`` reject a coalesced batch must be caught here — a
        poison row inside a batch would otherwise fail its innocent
        batch-mates."""
        arr = np.asarray(words, dtype=np.int64)
        if arr.ndim != 1:
            raise ValueError(f"submit takes one flat query, got shape "
                             f"{arr.shape}; submit batch rows individually "
                             "(coalescing is the server's job)")
        key = tuple(int(w) for w in arr)
        if not key:
            raise ValueError("empty query")
        V = self.engine.model.vocab_size
        bad = [w for w in key if not 1 <= w < V]
        if bad:
            raise ValueError(f"query word ids must be in [1, {V}); got {bad}")
        if profile.df_cap is not None:
            # reuse the facade's own cap formula (no drift) on the already-
            # validated ids — skipping suggested_df_cap's full re-encode
            # keeps the per-submit cost to one small fancy-index
            ranks = np.asarray(self.engine.model.rank_of_word)[list(key)]
            need = self.engine._df_cap(ranks[None, :],
                                       np.ones((1, len(key)), bool))
            if need > profile.df_cap:
                raise ValueError(
                    f"query needs df_cap {need} but this profile pins "
                    f"{profile.df_cap}; route it to a wider profile")
        return key

    def _lane_of(self, key: tuple[int, ...]) -> Lane:
        """df-predicted admission lane (DEFAULT_LANE when work bucketing is
        off or the engine exposes no df table — dummy engines still serve)."""
        if not self.work_buckets:
            return DEFAULT_LANE
        df = getattr(self.engine, "_df_np", None)
        rank_of = getattr(getattr(self.engine, "model", None),
                          "rank_of_word", None)
        if df is None or rank_of is None:
            return DEFAULT_LANE
        work = int(df[np.asarray(rank_of)[list(key)]].sum())
        heavy = work >= self.heavy_df
        return Lane(bucket=work_bucket(work), cap=1 if heavy else None)

    def _effective(self, profile: QueryProfile,
                   deadline_ms: float | None) -> tuple[QueryProfile, bool]:
        """Resolve a request's admission-time SLA into the *effective*
        profile the engine will run (DESIGN.md §11 degradation ladder):

        1. ``sla`` defaults per the engine config, auto-promoted to
           "bounded" when the request carries a budget or deadline;
        2. a deadline becomes a pop budget at the live us/pop estimate
           (min-combined with an explicit budget);
        3. under queue pressure (backlog >= 3/4 depth) non-exact traffic is
           *degraded*: sla forced to "best_effort", budget shrunk 4x (floor
           ``MIN_BUDGET``) so admitted work drains the backlog;
        4. shedding (queue physically full / draining) stays in submit —
           it is the ladder's last rung, not a profile.

        Returns ``(effective_profile, degraded)``; the effective profile has
        ``deadline_ms=None`` (already folded into ``budget``), so batcher
        grouping and cache keys see only concrete executor knobs.
        """
        dl = deadline_ms if deadline_ms is not None else profile.deadline_ms
        sla = profile.sla
        if sla is not None and sla not in SLA_CLASSES:
            raise ValueError(f"unknown sla {sla!r}; expected one of "
                             f"{SLA_CLASSES}")
        if dl is not None and float(dl) <= 0:
            raise ValueError(f"deadline_ms must be positive, got {dl}")
        anytime = profile.budget is not None or dl is not None
        if sla is None:
            cfg = getattr(self.engine, "config", None)
            sla = "bounded" if anytime else \
                getattr(cfg, "default_sla", "exact")
        if sla == "exact":
            if anytime:
                raise ValueError("sla='exact' guarantees an uninterrupted "
                                 "search — budget/deadline_ms require "
                                 "sla='bounded' or 'best_effort'")
            if profile.sla == "exact" and profile.deadline_ms is None:
                return profile, False
            return dataclasses.replace(profile, sla="exact",
                                       deadline_ms=None), False
        budget = profile.budget
        if dl is not None:
            conv = getattr(self.engine, "budget_for_deadline", None)
            if conv is not None:
                db = conv(dl)
                if db is not None:
                    budget = db if budget is None else min(int(budget), db)
        degraded = False
        if self._queue.qsize() >= self._degrade_at:
            from repro.engine.facade import budget_bucket
            full = 2 * int(getattr(self.engine, "n_docs", 1 << 29)) + 2
            base = full if budget is None else int(budget)
            budget = max(MIN_BUDGET, budget_bucket(max(1, base // 4)))
            if budget >= full:      # tiny corpora: the "shrunk" budget
                budget = MIN_BUDGET  # must actually cut work
            sla, degraded = "best_effort", True
        return dataclasses.replace(profile, sla=sla, budget=budget,
                                   deadline_ms=None), degraded

    def submit(self, words, profile: QueryProfile = DEFAULT_PROFILE,
               deadline_ms: float | None = None) -> Ticket:
        """Admit one query; never blocks.  Cache hits complete immediately;
        a full admission queue — or a drain in progress (:meth:`swap_engine`)
        — raises :class:`ShedError`.  ``deadline_ms`` overrides the
        profile's own; see :meth:`_effective` for the SLA ladder."""
        if self._thread is None:
            raise RuntimeError("server not started")
        key = self._normalize(words, profile)
        profile, degraded = self._effective(profile, deadline_ms)
        ticket = Ticket(key, profile)
        ticket.degraded = degraded
        if degraded:
            with self._lock:
                self.n_degraded += 1
            self._m_req["degraded"].inc()
        if self.obs.enabled:
            ticket.timeline = Timeline(ticket.t_submit)
        with self._lock:
            self.n_submitted += 1
        self._m_req["submitted"].inc()
        cached = self.cache.get((key, profile, self._tag))
        if cached is not None:
            ticket.cache_hit = True
            ticket.batch_size = 1
            ticket._complete(result=cached)
            with self._lock:
                self.n_served += 1
            self._m_req["served"].inc()
            self._m_req["cache_hit"].inc()
            self._record_stages(ticket)
            return ticket
        lane = self._lane_of(key)
        with self._lock:
            if self._draining:
                self.n_shed += 1
                self._m_req["shed"].inc()
                raise ShedError("engine swap in progress (draining); "
                                "retry shortly")
            # counted before the put so a swap can never observe 0 while an
            # admitted request is still on its way to the dispatch thread
            self._n_inflight += 1
        if ticket.timeline is not None:
            ticket.timeline.mark("admit")
        try:
            self._queue.put_nowait((key, profile, ticket, time.monotonic(),
                                    lane))
        except queue.Full:
            with self._lock:
                self._n_inflight -= 1
                self.n_shed += 1
            self._m_req["shed"].inc()
            raise ShedError(f"admission queue full "
                            f"({self._queue.maxsize} deep); retry later")
        return ticket

    def search(self, words, profile: QueryProfile = DEFAULT_PROFILE,
               timeout: float | None = 60.0,
               deadline_ms: float | None = None) -> RowResult:
        """Blocking submit -> result."""
        return self.submit(words, profile, deadline_ms=deadline_ms
                           ).result(timeout)

    def swap_engine(self, new_engine, *, drain_timeout: float = 60.0):
        """Hot-swap the engine: **drain -> swap -> clear cache**.

        New admissions shed (``ShedError``) while the drain runs; every
        request admitted *before* the swap completes against the old engine
        (its answers stay version-consistent), then the engine reference and
        cache tag flip and the result cache is cleared — tagged keys make
        the clear belt-and-braces: even a surviving entry could never match
        a key built with the new tag.  Returns the old engine.
        """
        if self._thread is None:
            raise RuntimeError("server not started")
        with self._lock:
            if self._draining:
                raise RuntimeError("another swap is already draining")
            self._draining = True
        try:
            deadline = time.monotonic() + drain_timeout
            while True:
                with self._lock:
                    if self._n_inflight == 0:
                        break
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"drain did not finish in {drain_timeout}s "
                        f"({self._n_inflight} requests still in flight)")
                time.sleep(0.001)
            if hasattr(new_engine, "obs_registry"):
                new_engine.obs_registry = self.obs
            old, self.engine = self.engine, new_engine
            self._tag = getattr(new_engine, "content_tag", None)
            if not self._heavy_df_explicit:     # re-derive for the new corpus
                self.heavy_df = 2 * int(getattr(new_engine, "n_docs", 1 << 29))
            self.cache.clear()
            with self._lock:
                self.n_swaps += 1
            self._m_swaps.inc()
            return old
        finally:
            with self._lock:
                self._draining = False

    # -- dispatch thread -----------------------------------------------------

    def _run(self):
        while self._running or not self._queue.empty() \
                or self._batcher._pending:
            batch = self._batcher.next_batch()
            if batch is not None:
                self._dispatch(batch)

    def _record_stages(self, ticket: Ticket) -> None:
        """Fold one completed ticket's span timeline into the per-stage
        latency histograms (no-op when the registry is disabled)."""
        if ticket.timeline is None:
            return
        for stage, dt in stage_durations(ticket.timeline).items():
            self._m_stage[stage].observe(dt)

    def _dispatch(self, batch: Batch):
        t0 = time.monotonic()
        for t in batch.items:
            t.t_dispatch = t0
            if t.timeline is not None:
                t.timeline.mark("dispatch", t0)
        for t in batch.items:
            t.batch_size = batch.n_real
        try:
            res = self.engine.search(batch.queries,
                                     **batch.profile.search_kwargs())
        except Exception as e:                    # profile-level failure
            for t in batch.items:
                t._complete(error=e)
            self._m_req["error"].inc(batch.n_real)
            with self._lock:
                self.n_errors += batch.n_real
                self._n_inflight -= batch.n_real
            return
        if self.obs.enabled:
            # force device completion so the device/slice split is real
            # (values are unchanged — DESIGN.md §10 exactness argument)
            np.asarray(res.docs)
            t_dev = time.monotonic()
            for t in batch.items:
                if t.timeline is not None:
                    t.timeline.mark("device", t_dev)
        dt = time.monotonic() - t0
        self._step += 1
        if self._watchdog.observe(self._step, dt):
            with self._lock:
                self.n_stragglers += 1
            self._m_straggler.inc()
        # feed the engine's deadline->budget estimator from *unbudgeted*
        # batches (a budget-cut batch would bias the pop cost optimistic)
        pops_arr = getattr(res, "pops", None)
        if batch.profile.budget is None and pops_arr is not None:
            note = getattr(self.engine, "note_cost", None)
            if note is not None:
                p = np.asarray(pops_arr).ravel()
                if len(p):
                    note(dt, float(p.mean()))
        rows = _slice_rows(res, batch.n_real)
        if self.obs.enabled:
            t_slice = time.monotonic()
            for t in batch.items:
                if t.timeline is not None:
                    t.timeline.mark("slice", t_slice)
        n_over = n_pad = 0
        for t, row in zip(batch.items, rows):
            self.cache.put((t.words, t.profile, self._tag), row)
            t._complete(result=row)
            self._record_stages(t)
            n_over += bool(row.overflowed)
            n_pad += row.padded or 0
        self._m_req["served"].inc(batch.n_real)
        self._m_overflow.inc(n_over)
        self._m_padded.inc(n_pad)
        self._m_dispatch.observe(dt)
        with self._lock:
            self.n_overflowed += n_over
            self.n_padded += n_pad
            self.n_served += batch.n_real
            self._n_inflight -= batch.n_real
            self.batch_hist[batch.n_real] = \
                self.batch_hist.get(batch.n_real, 0) + 1
            self.dispatch_s += dt

    # -- introspection -------------------------------------------------------

    @property
    def stats(self) -> dict:
        # Two-phase snapshot: the server's own counters come out under the
        # server lock (mutually consistent), then the engine and cache are
        # asked for *their* snapshots outside it — each is internally
        # consistent under its own lock, and taking the engine reference
        # under the server lock means a concurrent swap_engine can never
        # double-count (we read one engine's stats, whole, never a blend of
        # old and new).
        with self._lock:
            engine = self.engine
            n_batches = sum(self.batch_hist.values())
            out = {
                "submitted": self.n_submitted,
                "served": self.n_served,
                "shed": self.n_shed,
                "degraded": self.n_degraded,
                "stragglers": self.n_stragglers,
                "errors": self.n_errors,
                "swaps": self.n_swaps,
                "inflight": self._n_inflight,
                "engine_tag": self._tag,
                "overflowed": self.n_overflowed,
                "padded": self.n_padded,
                "dispatches": n_batches,
                "batch_hist": dict(sorted(self.batch_hist.items())),
                "mean_batch": sum(b * c for b, c in self.batch_hist.items())
                              / n_batches if n_batches else 0.0,
                "dispatch_s": self.dispatch_s,
            }
        out["cache"] = self.cache.stats
        estats = engine.stats          # dict-shaped for dummy engines too
        out["executors"] = estats["executors"]
        out["traces"] = sum(estats["traces"].values())
        return out


def _slice_rows(res, n_real: int) -> list[RowResult]:
    """Split a batched SearchResults into per-request host rows (pad rows
    past ``n_real`` are dropped)."""
    docs = np.asarray(res.docs)
    scores = np.asarray(res.scores)
    n_found = np.asarray(res.n_found)
    work = np.asarray(res.work)
    pops = None if res.pops is None else np.asarray(res.pops)
    over = None if res.overflowed is None else np.asarray(res.overflowed)
    pad = getattr(res, "padded", None)       # dummy engines may omit the field
    pad = None if pad is None else np.asarray(pad)
    cert = getattr(res, "certified", None)
    cert = None if cert is None else np.asarray(cert)
    bnd = getattr(res, "score_bound", None)
    bnd = None if bnd is None else np.asarray(bnd)
    sla = getattr(res, "sla", "exact")
    mp = None if res.match_pos is None else np.asarray(res.match_pos)
    ml = None if res.match_len is None else np.asarray(res.match_len)
    return [RowResult(
        docs=docs[b], scores=scores[b], n_found=int(n_found[b]),
        work=int(work[b]), k=res.k, mode=res.mode, strategy=res.strategy,
        measure=res.measure,
        pops=None if pops is None else int(pops[b]),
        overflowed=None if over is None else bool(over[b]),
        padded=None if pad is None else int(pad[b]),
        certified=None if cert is None else cert[b],
        score_bound=None if bnd is None else float(bnd[b]),
        sla=sla,
        match_pos=None if mp is None else mp[b],
        match_len=None if ml is None else ml[b]) for b in range(n_real)]
