"""Dynamic micro-batching: coalesce concurrent single queries into one
batched executor call.

The engine's executors are compiled per ``(B, Q)`` bucket, so the scheduler's
job is to gather whatever requests are in flight into the *largest batch the
wait budget allows* and pad it onto one of a small fixed set of shapes:

* **admission**: requests queue up with a profile (mode/strategy/measure/k/…);
* **coalescing**: once a request is at the head, the batcher waits at most
  ``max_wait_ms`` for followers (first-request deadline — a lone query never
  waits longer than that) and takes at most ``max_batch``;
* **grouping**: only requests with the *same profile* share an executor call
  (they must — the profile IS the executor configuration).  Mixed-profile
  traffic is split into per-profile batches, head-of-queue profile first;
* **bucketing**: the batch dim is padded up to a power of two by repeating a
  real row (results of pad rows are dropped), and the facade pads Q the same
  way — so steady traffic reuses O(log max_batch · log max_Q) compiled
  programs per profile, which ``SearchEngine.warmup`` precompiles.

Exactness: executors are vmapped over rows and masked over pad columns, so
coalescing/padding cannot change any row's answer (DESIGN.md §7 pins this
bitwise in tests).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

from repro.engine.facade import pow2_bucket


@dataclasses.dataclass(frozen=True)
class QueryProfile:
    """Everything that selects an executor, besides the batch itself.

    Hashable — the batcher groups by it and the cache keys on it.  ``df_cap``
    should be pinned (``SearchEngine.suggested_df_cap``) for
    ``strategy='drb', mode='or'`` traffic so the gather width — normally
    derived per batch — stays static across mixed batches.
    """
    mode: str = "and"
    strategy: str = "auto"
    measure: str = "tfidf"
    k: int | None = None
    window: int | None = None
    budget: int | None = None
    beam_width: int | None = None
    df_cap: int | None = None

    def search_kwargs(self) -> dict:
        return dict(mode=self.mode, strategy=self.strategy,
                    measure=self.measure, k=self.k, window=self.window,
                    budget=self.budget, beam_width=self.beam_width,
                    df_cap=self.df_cap)


@dataclasses.dataclass
class Batch:
    """One coalesced executor call: ``items`` are the real requests (any
    payload the caller tracks), ``queries`` the padded row list sent to the
    engine (``len(queries) = pow2_bucket(len(items))``)."""
    profile: QueryProfile
    items: list
    queries: list[list[int]]

    @property
    def n_real(self) -> int:
        return len(self.items)


def pad_rows(rows: list[list[int]]) -> list[list[int]]:
    """Pad the batch dim to its power-of-two bucket by repeating row 0 —
    a real query, so no masking/validity special case exists; the extra
    rows' results are simply dropped."""
    return rows + [rows[0]] * (pow2_bucket(len(rows)) - len(rows))


class MicroBatcher:
    """Pulls (words, profile, item) tuples from a source and yields padded
    per-profile batches under the max-wait / max-batch policy.

    ``source(timeout)`` must return one admitted request or raise
    ``queue.Empty`` — the stdlib queue contract — so the server can hand its
    bounded admission queue straight in.  The batcher keeps requests it has
    accepted but not yet batched in an internal deque (arrival order), so
    nothing is ever dropped here; shedding happens at admission.
    """

    def __init__(self, source: Callable, *, max_batch: int = 16,
                 max_wait_ms: float = 2.0, pending_cap: int | None = None,
                 clock=time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self._source = source
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        # bound on requests held here awaiting a same-profile batch: without
        # it, assembling a profile-A batch under a flood of profile-B traffic
        # would drain the (bounded) admission queue into this (unbounded)
        # deque and the shed policy would never engage
        self.pending_cap = max(max_batch, pending_cap or 4 * max_batch)
        self._clock = clock
        self._pending: deque = deque()    # (words, profile, item, t_admit)

    def _pull(self, timeout: float) -> bool:
        import queue as _q
        try:
            self._pending.append(self._source(timeout=max(0.0, timeout)))
            return True
        except _q.Empty:
            return False

    def next_batch(self, poll_s: float = 0.05) -> Batch | None:
        """Block up to ``poll_s`` for traffic, then coalesce and return one
        batch — or None if the queue stayed empty (callers loop on this, so
        shutdown flags get re-checked every ``poll_s``)."""
        if not self._pending and not self._pull(poll_s):
            return None
        # head request sets the deadline: wait for followers until the head
        # has been held max_wait, or a full batch of its profile is ready.
        # Requests already queued (e.g. admitted while the previous batch was
        # computing) are always drained first, without waiting — the wait
        # budget is only ever spent on traffic that hasn't arrived yet.
        head_profile = self._pending[0][1]
        deadline = self._pending[0][3] + self.max_wait
        # running head-profile count: one scan of the leftover deque, then
        # O(1) per pull — batch assembly must stay cheap on the dispatch
        # thread, which is the path the batcher exists to protect
        n_head = sum(1 for r in self._pending if r[1] == head_profile)

        def may_pull() -> bool:
            return (n_head < self.max_batch
                    and len(self._pending) < self.pending_cap)

        def pull(timeout: float) -> bool:
            nonlocal n_head
            if not self._pull(timeout):
                return False
            n_head += self._pending[-1][1] == head_profile
            return True

        while may_pull() and pull(0.0):
            pass
        while may_pull():
            remaining = deadline - self._clock()
            if remaining <= 0 or not pull(remaining):
                break
            while may_pull() and pull(0.0):
                pass
        taken, rest = [], deque()
        for r in self._pending:
            if r[1] == head_profile and len(taken) < self.max_batch:
                taken.append(r)
            else:
                rest.append(r)
        self._pending = rest
        rows = [list(words) for words, _, _, _ in taken]
        return Batch(profile=head_profile,
                     items=[item for _, _, item, _ in taken],
                     queries=pad_rows(rows))
