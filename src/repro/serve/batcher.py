"""Dynamic micro-batching: coalesce concurrent single queries into one
batched executor call.

The engine's executors are compiled per ``(B, Q)`` bucket, so the scheduler's
job is to gather whatever requests are in flight into the *largest batch the
wait budget allows* and pad it onto one of a small fixed set of shapes:

* **admission**: requests queue up with a profile (mode/strategy/measure/k/…);
* **coalescing**: once a request is at the head, the batcher waits at most
  ``max_wait_ms`` for followers (first-request deadline — a lone query never
  waits longer than that) and takes at most ``max_batch``;
* **grouping**: only requests with the *same profile AND the same work lane*
  share an executor call (the profile IS the executor configuration; the
  lane keeps predicted-heavy rows from riding along).  Mixed traffic is
  split into per-(profile, lane) batches, head-of-queue group first;
* **bucketing**: the batch dim is padded up to a power of two by repeating a
  real row (results of pad rows are dropped), and the facade pads Q the same
  way — so steady traffic reuses O(log max_batch · log max_Q) compiled
  programs per profile, which ``SearchEngine.warmup`` precompiles.

**Work lanes** (DESIGN.md §8): a batched search runs until its *slowest*
row finishes, so one heavy query inside a batch of light ones taxes every
batch-mate with its full latency.  The server predicts per-query work from
the sum of query-word document frequencies (df is exactly what drives the
DR frontier and the DRB walk) and maps it to a factor-8 bucket
(:func:`work_bucket`); the batcher then only coalesces within a bucket, and
queries past the heavy threshold ride a ``cap=1`` lane — admitted, never
batched with anyone.

**Adaptive wait**: with ``adaptive_wait`` on, the batcher tracks an EWMA of
request inter-arrival gaps; when the stream is idle (expected gap beyond
``max_wait``) the wait budget collapses to 0 — a lone query on an idle
server pays dispatch latency only, while bursty traffic still coalesces.

Exactness: executors are vmapped over rows and masked over pad columns, so
coalescing/padding/lane-splitting cannot change any row's answer
(DESIGN.md §7 pins this bitwise in tests).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import repro.obs as obs
from repro.engine.facade import pow2_bucket

EWMA_ALPHA = 0.3        # inter-arrival smoothing (recent gaps dominate)


def work_bucket(work: int) -> int:
    """Factor-8 work bucket of a predicted per-query cost (e.g. the sum of
    query-word document frequencies): 0 for [0, 8), 1 for [8, 64), ...
    Factor 8 is coarse enough that steady traffic occupies a handful of
    lanes, fine enough that a bucket's slowest member costs its batch-mates
    at most ~8x their own work."""
    b, w = 0, max(int(work), 1)
    while w >= 8:
        w //= 8
        b += 1
    return b


@dataclasses.dataclass(frozen=True)
class Lane:
    """Admission lane: requests coalesce only within (profile, lane).

    ``bucket`` is the factor-8 work bucket; ``cap`` bounds the batch size
    for this lane (1 isolates predicted-heavy queries; None defers to the
    batcher's ``max_batch``)."""
    bucket: int = 0
    cap: int | None = None


DEFAULT_LANE = Lane()


@dataclasses.dataclass(frozen=True)
class QueryProfile:
    """Everything that selects an executor, besides the batch itself.

    Hashable — the batcher groups by it and the cache keys on it.  ``df_cap``
    should be pinned (``SearchEngine.suggested_df_cap``) for
    ``strategy='drb', mode='or'`` traffic so the gather width — normally
    derived per batch — stays static across mixed batches.

    ``sla``/``deadline_ms`` are *admission-time* knobs (DESIGN.md §11): the
    server resolves them into a concrete ``budget`` + ``sla`` *effective
    profile* at submit (``deadline_ms`` never reaches the engine), so two
    requests degrade into the same effective profile batch together and the
    cache can never replay a degraded answer for an exact request.
    """
    mode: str = "and"
    strategy: str = "auto"
    measure: str = "tfidf"
    k: int | None = None
    window: int | None = None
    budget: int | None = None
    beam_width: int | None = None
    df_cap: int | None = None
    mega: bool | None = None
    sla: str | None = None
    deadline_ms: float | None = None

    def search_kwargs(self) -> dict:
        # deadline_ms is deliberately absent: the serving layer folds it
        # into ``budget`` at admission; direct engine.search callers pass
        # their own deadline_ms explicitly
        return dict(mode=self.mode, strategy=self.strategy,
                    measure=self.measure, k=self.k, window=self.window,
                    budget=self.budget, beam_width=self.beam_width,
                    df_cap=self.df_cap, mega=self.mega, sla=self.sla)


@dataclasses.dataclass
class Batch:
    """One coalesced executor call: ``items`` are the real requests (any
    payload the caller tracks), ``queries`` the padded row list sent to the
    engine (``len(queries) = pow2_bucket(len(items))``)."""
    profile: QueryProfile
    items: list
    queries: list[list[int]]
    lane: Lane = DEFAULT_LANE

    @property
    def n_real(self) -> int:
        return len(self.items)


def pad_rows(rows: list[list[int]]) -> list[list[int]]:
    """Pad the batch dim to its power-of-two bucket by repeating row 0 —
    a real query, so no masking/validity special case exists; the extra
    rows' results are simply dropped."""
    return rows + [rows[0]] * (pow2_bucket(len(rows)) - len(rows))


class MicroBatcher:
    """Pulls ``(words, profile, item, t_admit[, lane])`` tuples from a source
    and yields padded per-(profile, lane) batches under the max-wait /
    max-batch policy.

    ``source(timeout)`` must return one admitted request or raise
    ``queue.Empty`` — the stdlib queue contract — so the server can hand its
    bounded admission queue straight in.  The batcher keeps requests it has
    accepted but not yet batched in an internal deque (arrival order), so
    nothing is ever dropped here; shedding happens at admission.

    Starvation bound: the batch is always formed around the *oldest* pending
    request (head of the deque), whatever its lane — a heavy ``cap=1``
    request is dispatched as soon as it reaches the head, so lane isolation
    delays it by at most the batches admitted before it, never indefinitely
    (tests/test_mega.py pins this).
    """

    def __init__(self, source: Callable, *, max_batch: int = 16,
                 max_wait_ms: float = 2.0, pending_cap: int | None = None,
                 adaptive_wait: bool = False, clock=time.monotonic,
                 registry: "obs.Registry | None" = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self._source = source
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.adaptive_wait = adaptive_wait
        # bound on requests held here awaiting a same-profile batch: without
        # it, assembling a profile-A batch under a flood of profile-B traffic
        # would drain the (bounded) admission queue into this (unbounded)
        # deque and the shed policy would never engage
        self.pending_cap = max(max_batch, pending_cap or 4 * max_batch)
        self._clock = clock
        self._obs = obs.resolve(registry)
        self._m_wait = self._obs.histogram(
            "repro_batch_coalesce_wait_seconds", None,
            "head-request age when its batch formed")
        self._ewma_gap: float | None = None     # smoothed inter-arrival gap
        self._last_arrival: float | None = None
        self._pending: deque = deque()  # (words, profile, item, t_admit, lane)

    def _pull(self, timeout: float) -> bool:
        import queue as _q
        try:
            r = self._source(timeout=max(0.0, timeout))
        except _q.Empty:
            return False
        if len(r) == 4:                     # lane-less producers still work
            r = (*r, DEFAULT_LANE)
        self._pending.append(r)
        if self._obs.enabled:
            tl = getattr(r[2], "timeline", None)
            if tl is not None:
                tl.mark("lane_enqueue")
        now = self._clock()
        if self._last_arrival is not None:
            gap = now - self._last_arrival
            self._ewma_gap = gap if self._ewma_gap is None else (
                EWMA_ALPHA * gap + (1.0 - EWMA_ALPHA) * self._ewma_gap)
        self._last_arrival = now
        return True

    def effective_wait(self) -> float:
        """The coalescing budget for the next batch: ``max_wait``, collapsed
        to 0 when ``adaptive_wait`` is on and the arrival stream looks idle
        (expected gap at or beyond ``max_wait`` — waiting would buy no
        batch-mates, only latency)."""
        if not self.adaptive_wait or self._ewma_gap is None:
            return self.max_wait
        return 0.0 if self._ewma_gap >= self.max_wait else self.max_wait

    def next_batch(self, poll_s: float = 0.05) -> Batch | None:
        """Block up to ``poll_s`` for traffic, then coalesce and return one
        batch — or None if the queue stayed empty (callers loop on this, so
        shutdown flags get re-checked every ``poll_s``)."""
        if not self._pending and not self._pull(poll_s):
            return None
        # head request sets the deadline: wait for followers until the head
        # has been held max_wait, or a full batch of its group is ready.
        # Requests already queued (e.g. admitted while the previous batch was
        # computing) are always drained first, without waiting — the wait
        # budget is only ever spent on traffic that hasn't arrived yet.
        head = self._pending[0]
        group = (head[1], head[4])              # (profile, lane)
        cap = min(self.max_batch, head[4].cap or self.max_batch)
        deadline = head[3] + self.effective_wait()
        # running head-group count: one scan of the leftover deque, then
        # O(1) per pull — batch assembly must stay cheap on the dispatch
        # thread, which is the path the batcher exists to protect
        n_head = sum(1 for r in self._pending if (r[1], r[4]) == group)

        def may_pull() -> bool:
            return n_head < cap and len(self._pending) < self.pending_cap

        def pull(timeout: float) -> bool:
            nonlocal n_head
            if not self._pull(timeout):
                return False
            r = self._pending[-1]
            n_head += (r[1], r[4]) == group
            return True

        while may_pull() and pull(0.0):
            pass
        while may_pull():
            remaining = deadline - self._clock()
            if remaining <= 0 or not pull(remaining):
                break
            while may_pull() and pull(0.0):
                pass
        taken, rest = [], deque()
        for r in self._pending:
            if (r[1], r[4]) == group and len(taken) < cap:
                taken.append(r)
            else:
                rest.append(r)
        self._pending = rest
        if self._obs.enabled:
            lane = group[1]
            self._obs.histogram(
                "repro_batch_size",
                {"lane": f"{lane.bucket}/{lane.cap or 'max'}"},
                "real rows per coalesced batch, by admission lane",
            ).observe(len(taken))
            self._m_wait.observe(self._clock() - taken[0][3])
            for _, _, item, _, _ in taken:
                tl = getattr(item, "timeline", None)
                if tl is not None:
                    tl.mark("batch_form")
        rows = [list(words) for words, _, _, _, _ in taken]
        return Batch(profile=group[0],
                     items=[item for _, _, item, _, _ in taken],
                     queries=pad_rows(rows), lane=group[1])
