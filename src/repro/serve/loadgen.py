"""Load generation + latency-percentile reporting for the serving subsystem.

Two standard generator shapes (the serving-systems literature distinguishes
them because they bound different things):

* **closed loop** — ``n_workers`` clients issue back-to-back requests; this
  measures *sustainable throughput* at a fixed concurrency (the micro-batcher
  comparison in ``benchmarks/table6_serving.py`` runs this shape);
* **open loop** — requests arrive on a Poisson (or fixed-interval) schedule at
  ``target_qps`` regardless of completions; this measures the *latency
  distribution under a given offered load* including queueing, and exercises
  the shed policy when the load exceeds capacity.

Queries can be sampled straight from a (possibly snapshot-restored) engine —
no corpus needed: document frequencies live in the index and the id<->rank
maps in the model, which is all band-based sampling requires.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import wtbc
from repro.serve.server import (DEFAULT_PROFILE, RequestTimeout, SearchServer,
                                ShedError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded jittered exponential backoff for :class:`ShedError` retries.

    A shed is the server telling the client "elsewhere, or later" —
    retrying instantly would synchronize the rejected cohort into a retry
    storm, so each attempt waits ``base_ms * 2**attempt`` plus uniform
    jitter of the same magnitude (full jitter; deterministic under
    ``seed`` so load runs reproduce).  ``max_retries=0`` disables retry —
    the pre-existing behavior."""
    max_retries: int = 0
    base_ms: float = 2.0
    seed: int = 0

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        step = self.base_ms * (2.0 ** attempt) / 1e3
        return step + float(rng.uniform(0.0, step))


NO_RETRY = RetryPolicy()


def sample_queries(engine, n_queries: int, words_per_query: int = 3, *,
                   df_range: tuple[int, int] | None = None,
                   seed: int = 0) -> list[list[int]]:
    """Query word-id lists drawn from the engine's own df table (band
    sampling like ``text.corpus.sample_queries``, but corpus-free so a
    snapshot-only server can generate traffic).  ``df_range`` defaults to
    [2, 5% of docs] — the interactive band where queries are selective."""
    df = np.asarray(engine.idx.df)
    if df.ndim == 2:                      # sharded: per-shard df -> global-ish
        df = np.asarray(engine._sharded.global_df)
    lo, hi = df_range or (2, max(3, int(engine.n_docs) // 20))
    pool_ranks = np.flatnonzero((df >= lo) & (df <= hi))
    pool_ranks = pool_ranks[pool_ranks > 0]          # never the '$' separator
    if len(pool_ranks) < words_per_query:
        raise ValueError(f"df band [{lo}, {hi}] holds only {len(pool_ranks)} "
                         "words; widen df_range")
    word_of_rank = np.asarray(engine.model.word_of_rank)
    rng = np.random.default_rng(seed)
    return [[int(w) for w in word_of_rank[
        rng.choice(pool_ranks, words_per_query, replace=False)]]
        for _ in range(n_queries)]


def sample_ngram_queries(engine, n_queries: int, q_len: int = 3, *,
                         seed: int = 0) -> list[list[int]]:
    """Consecutive-token queries decoded straight from the compressed index
    (no corpus): random document, random offset, ``q_len`` tokens.  The
    phrase/near workload generator — independently sampled words essentially
    never co-occur, which would make a positional load test measure only the
    empty-match fast path."""
    if engine.backend != "single":
        raise ValueError("n-gram sampling reads the single-host index "
                         "(positional modes are single-host anyway)")
    doc_len = np.asarray(engine.idx.doc_len)
    eligible = np.flatnonzero(doc_len >= q_len)
    if not len(eligible):
        raise ValueError(f"no document holds {q_len} tokens")
    word_of_rank = np.asarray(engine.model.word_of_rank)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_queries):
        d = int(rng.choice(eligible))
        off = int(rng.integers(0, doc_len[d] - q_len + 1))
        lo = wtbc.doc_start(engine.idx, jnp.int32(d)) + off
        ranks = np.asarray(wtbc.extract(engine.idx, lo, q_len))
        out.append([int(w) for w in word_of_rank[ranks]])
    return out


def zipf_workload(queries: list, n_requests: int, *, alpha: float = 1.1,
                  seed: int = 0) -> list:
    """A request stream with Zipf-repeated queries (real query logs are
    heavily skewed — this is what makes result caches earn their keep)."""
    probs = 1.0 / np.arange(1, len(queries) + 1) ** alpha
    probs /= probs.sum()
    rng = np.random.default_rng(seed)
    return [queries[i] for i in rng.choice(len(queries), n_requests, p=probs)]


def _pcts(ms: np.ndarray) -> tuple[float, float, float]:
    if len(ms):
        return (float(np.percentile(ms, 50)), float(np.percentile(ms, 95)),
                float(np.percentile(ms, 99)))
    nan = float("nan")
    return nan, nan, nan


def stage_breakdown(server: SearchServer) -> dict | None:
    """Registry-derived per-stage latency attribution (milliseconds): the
    ``repro_request_stage_seconds`` histograms the server recorded, one entry
    per stage (queue_wait / device / slice / total), each with reconstructed
    p50/p95/p99, mean, and count.  None when the server's registry is
    disabled or no stage was recorded — callers (table6/table7, BENCH)
    emit the field only when observability was on."""
    reg = getattr(server, "obs", None)
    if reg is None or not reg.enabled:
        return None
    out = {}
    for h in reg.find("repro_request_stage_seconds"):
        stage = dict(h.labels).get("stage", "?")
        if h.n == 0:
            continue
        p = h.percentiles((50, 95, 99))
        out[stage] = {"p50_ms": p["p50"] * 1e3, "p95_ms": p["p95"] * 1e3,
                      "p99_ms": p["p99"] * 1e3, "mean_ms": h.mean * 1e3,
                      "count": h.n}
    return out or None


@dataclasses.dataclass
class LoadReport:
    """What one load-generation run measured (latencies in milliseconds).
    ``n_err`` counts requests the server answered with an error — they are
    excluded from the latency/throughput numbers, never silently blended.

    Total latency decomposes exactly per request into **queue wait**
    (submit -> dispatch: admission backlog + coalescing) and **service**
    (dispatch -> complete: engine + host slice); both percentile sets are
    reported so capacity problems (queue grows) read differently from
    kernel regressions (service grows).  ``stages`` is the finer
    registry-derived breakdown (:func:`stage_breakdown`) when the server
    ran with observability enabled, else None."""
    n_ok: int
    n_shed: int
    n_err: int
    n_timeout: int
    duration_s: float
    qps: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    mean_ms: float
    latencies_ms: np.ndarray
    server_stats: dict
    queue_p50_ms: float = float("nan")
    queue_p95_ms: float = float("nan")
    queue_p99_ms: float = float("nan")
    service_p50_ms: float = float("nan")
    service_p95_ms: float = float("nan")
    service_p99_ms: float = float("nan")
    queue_ms: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0))
    service_ms: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0))
    stages: dict | None = None
    # anytime/SLA accounting (DESIGN.md §11): degraded = admission shrank
    # the budget; certified_fraction = certified slots / found slots over
    # the served answers; retry_hist = attempts-needed -> requests (0 =
    # first try; only present when a RetryPolicy was active)
    n_degraded: int = 0
    certified_fraction: float = 1.0
    n_retried: int = 0
    retry_hist: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_latencies(cls, lats_s: list[float], n_shed: int, n_err: int,
                       duration_s: float, server: SearchServer,
                       n_timeout: int = 0, queue_s: list[float] | None = None,
                       service_s: list[float] | None = None) -> "LoadReport":
        ms = np.asarray(sorted(lats_s)) * 1e3
        p50, p95, p99 = _pcts(ms)
        q_ms = np.asarray(sorted(queue_s or [])) * 1e3
        s_ms = np.asarray(sorted(service_s or [])) * 1e3
        qp = _pcts(q_ms)
        sp = _pcts(s_ms)
        return cls(n_ok=len(ms), n_shed=n_shed, n_err=n_err,
                   n_timeout=n_timeout, duration_s=duration_s,
                   qps=len(ms) / duration_s if duration_s > 0 else 0.0,
                   p50_ms=p50, p95_ms=p95, p99_ms=p99,
                   mean_ms=float(ms.mean()) if len(ms) else float("nan"),
                   latencies_ms=ms, server_stats=server.stats,
                   queue_p50_ms=qp[0], queue_p95_ms=qp[1], queue_p99_ms=qp[2],
                   service_p50_ms=sp[0], service_p95_ms=sp[1],
                   service_p99_ms=sp[2], queue_ms=q_ms, service_ms=s_ms,
                   stages=stage_breakdown(server))

    @classmethod
    def from_tickets(cls, tickets: list, n_shed: int, duration_s: float,
                     server: SearchServer, retry_hist: dict | None = None,
                     ) -> "LoadReport":
        """Build a report from completed tickets: total latency plus the
        queue-wait/service decomposition each ticket carries.  Tickets
        finalized with :class:`RequestTimeout` count as timeouts (the
        loadgen *cancels* in-flight tickets at its deadline — none are ever
        left dangling to complete into a later window); other errors count
        as ``n_err``; still-undone tickets (a caller that skipped the cancel
        pass) also count as timeouts."""
        ok = [t for t in tickets
              if t.done() and t.error is None and t.latency_s is not None]
        timeouts = sum(1 for t in tickets if not t.done()
                       or isinstance(t.error, RequestTimeout))
        errs = sum(1 for t in tickets if t.done() and t.error is not None
                   and not isinstance(t.error, RequestTimeout))
        slots = cert = 0
        for t in ok:
            row = t._result
            n = getattr(row, "n_found", 0)
            slots += n
            nc = getattr(row, "n_certified", None)
            cert += n if nc is None else nc
        rep = cls.from_latencies(
            [t.latency_s for t in ok], n_shed, errs, duration_s, server,
            n_timeout=timeouts,
            queue_s=[t.queue_wait_s for t in ok],
            service_s=[t.service_s for t in ok])
        rep.n_degraded = sum(1 for t in tickets
                             if getattr(t, "degraded", False))
        rep.certified_fraction = cert / slots if slots else 1.0
        if retry_hist:
            rep.retry_hist = dict(sorted(retry_hist.items()))
            rep.n_retried = sum(c for a, c in retry_hist.items() if a > 0)
        return rep

    def summary(self) -> str:
        out = (f"{self.n_ok} ok / {self.n_shed} shed / {self.n_err} err in "
               f"{self.duration_s:.2f}s"
               f" | {self.qps:.0f} q/s | p50 {self.p50_ms:.1f}ms"
               f" | p95 {self.p95_ms:.1f}ms | p99 {self.p99_ms:.1f}ms")
        if self.n_degraded or self.certified_fraction < 1.0:
            out += (f" | {self.n_degraded} degraded | certified "
                    f"{self.certified_fraction:.3f}")
        if self.n_retried:
            out += f" | {self.n_retried} retried {self.retry_hist}"
        if self.n_timeout:
            out += f" | {self.n_timeout} timed out"
        if len(self.queue_ms):
            out += (f" | queue p50/p95/p99 {self.queue_p50_ms:.1f}/"
                    f"{self.queue_p95_ms:.1f}/{self.queue_p99_ms:.1f}ms"
                    f" | service p50/p95/p99 {self.service_p50_ms:.1f}/"
                    f"{self.service_p95_ms:.1f}/{self.service_p99_ms:.1f}ms")
        return out


def closed_loop(server: SearchServer, workload: list, *,
                n_workers: int = 8, profile=DEFAULT_PROFILE,
                timeout_s: float = 120.0,
                retry: RetryPolicy = NO_RETRY) -> LoadReport:
    """``n_workers`` clients drain ``workload`` back-to-back (one outstanding
    request per client — arrival rate adapts to service rate).  With a
    :class:`RetryPolicy`, a shed request is retried after jittered backoff
    up to ``retry.max_retries`` times before counting as shed; the report's
    ``retry_hist`` maps attempts-needed -> admitted requests."""
    it = iter(range(len(workload)))
    it_lock = threading.Lock()
    done_tickets: list = []          # retained for the queue/service split
    shed = [0]
    retry_hist: dict[int, int] = {}
    rngs = [np.random.default_rng(retry.seed + w) for w in range(n_workers)]

    def client(w: int):
        while True:
            with it_lock:
                i = next(it, None)
            if i is None:
                return
            tk = None
            for attempt in range(retry.max_retries + 1):
                try:
                    tk = server.submit(workload[i], profile)
                except ShedError:
                    if attempt < retry.max_retries:
                        time.sleep(retry.backoff_s(attempt, rngs[w]))
                    continue
                with it_lock:
                    retry_hist[attempt] = retry_hist.get(attempt, 0) + 1
                break
            if tk is None:          # every attempt shed
                with it_lock:
                    shed[0] += 1
                continue
            try:
                tk.result(timeout_s)
            except Exception:       # dispatch error/timeout: the ticket
                pass                # carries it; keep the worker alive
            with it_lock:
                done_tickets.append(tk)

    threads = [threading.Thread(target=client, args=(w,))
               for w in range(n_workers)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return LoadReport.from_tickets(done_tickets, shed[0],
                                   time.monotonic() - t0, server,
                                   retry_hist=retry_hist or None)


def open_loop(server: SearchServer, workload: list, *, target_qps: float,
              profile=DEFAULT_PROFILE, poisson: bool = True, seed: int = 0,
              timeout_s: float = 120.0,
              retry: RetryPolicy = NO_RETRY) -> LoadReport:
    """Submit ``workload`` on a Poisson/fixed schedule at ``target_qps`` and
    wait for completions; sheds count, they don't block the schedule.

    With a :class:`RetryPolicy`, shed requests are re-queued after jittered
    backoff as *extra* arrivals (deferred — the original schedule is never
    blocked, matching how an open-loop client fleet actually behaves).

    At the wait deadline every still-in-flight ticket is **cancelled**
    (:meth:`Ticket.cancel` with :class:`RequestTimeout`): a late engine
    completion can no longer resurrect it, so the report's timeout count is
    final and nothing leaks into a later measurement window."""
    if target_qps <= 0:
        raise ValueError(f"target_qps must be > 0, got {target_qps}")
    rng = np.random.default_rng(seed)
    gaps = (rng.exponential(1.0 / target_qps, size=len(workload)) if poisson
            else np.full(len(workload), 1.0 / target_qps))
    t0 = time.monotonic()
    # event list: (due_time_rel, query, attempt); retries merge in deferred
    schedule = [(float(at), q, 0) for q, at in zip(workload, np.cumsum(gaps))]
    schedule.sort(key=lambda e: -e[0])      # pop() takes the earliest
    tickets, shed = [], 0
    retry_hist: dict[int, int] = {}
    while schedule:
        at, q, attempt = schedule.pop()
        lag = t0 + at - time.monotonic()
        if lag > 0:
            time.sleep(lag)
        try:
            tickets.append(server.submit(q, profile))
            retry_hist[attempt] = retry_hist.get(attempt, 0) + 1
        except ShedError:
            if attempt < retry.max_retries:
                due = (time.monotonic() - t0) + retry.backoff_s(attempt, rng)
                schedule.append((due, q, attempt + 1))
                schedule.sort(key=lambda e: -e[0])
            else:
                shed += 1
    deadline = time.monotonic() + timeout_s
    for t in tickets:
        t._event.wait(max(0.0, deadline - time.monotonic()))
    for t in tickets:                # finalize stragglers: no ticket leaks
        if not t.done():
            t.cancel(RequestTimeout(
                f"open_loop gave up after {timeout_s}s"))
    duration = time.monotonic() - t0
    return LoadReport.from_tickets(
        tickets, shed, duration, server,
        retry_hist=retry_hist if retry.max_retries else None)
