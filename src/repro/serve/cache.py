"""LRU result cache for the serving frontend.

Ranked retrieval over an immutable snapshot is a pure function of the
normalized request — ``(word ids, profile)`` — so caching is exact by
construction: a hit replays the stored answer for the *identical* key, it
never approximates.  Index updates need invalidation: the server versions
its keys with the engine's content tag and ``SearchServer.swap_engine``
clears the cache after the drain, so a hit can never cross engine versions
even mid-swap (DESIGN.md §8).

Thread-safe: ``get``/``put`` take a lock (submit threads race the dispatch
thread) and ``stats`` snapshots under the same lock — a reader can never
observe a half-updated hit/miss pair.  ``capacity=0`` disables caching
(every ``get`` is a miss, ``put`` drops), so callers don't need a second
code path.

Metrics: hits/misses/evictions mirror into a :mod:`repro.obs` registry
(labeled by ``name`` so several caches can share one registry); recording is
free while the registry is disabled (DESIGN.md §10).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable

import repro.obs as obs


class LRUCache:
    """Bounded least-recently-used map with hit/miss counters."""

    def __init__(self, capacity: int, *, registry: "obs.Registry | None" = None,
                 name: str = "result_cache"):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        reg = obs.resolve(registry)
        labels = {"cache": name}
        self._m_hits = reg.counter("repro_cache_hits_total", labels,
                                   "result-cache hits")
        self._m_misses = reg.counter("repro_cache_misses_total", labels,
                                     "result-cache misses")
        self._m_evictions = reg.counter("repro_cache_evictions_total", labels,
                                        "LRU entries evicted at capacity")

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Hashable):
        """The cached value (refreshing its recency) or None."""
        with self._lock:
            val = self._data.get(key)
            if val is None:
                self.misses += 1
                self._m_misses.inc()
                return None
            self._data.move_to_end(key)
            self.hits += 1
            self._m_hits.inc()
            return val

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)          # evict the LRU entry
                self._m_evictions.inc()

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    @property
    def stats(self) -> dict:
        with self._lock:                  # consistent (hits, misses, size)
            hits, misses, size = self.hits, self.misses, len(self._data)
        n = hits + misses
        return {"hits": hits, "misses": misses,
                "hit_rate": hits / n if n else 0.0,
                "size": size, "capacity": self.capacity}
