"""LRU result cache for the serving frontend.

Ranked retrieval over an immutable snapshot is a pure function of the
normalized request — ``(word ids, profile)`` — so caching is exact by
construction: a hit replays the stored answer for the *identical* key, it
never approximates.  Index updates need invalidation: the server versions
its keys with the engine's content tag and ``SearchServer.swap_engine``
clears the cache after the drain, so a hit can never cross engine versions
even mid-swap (DESIGN.md §8).

Thread-safe: ``get``/``put`` take a lock (submit threads race the dispatch
thread).  ``capacity=0`` disables caching (every ``get`` is a miss, ``put``
drops), so callers don't need a second code path.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable


class LRUCache:
    """Bounded least-recently-used map with hit/miss counters."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Hashable):
        """The cached value (refreshing its recency) or None."""
        with self._lock:
            val = self._data.get(key)
            if val is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)          # evict the LRU entry

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    @property
    def stats(self) -> dict:
        n = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / n if n else 0.0,
                "size": len(self._data), "capacity": self.capacity}
