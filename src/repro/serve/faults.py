"""Seeded fault injection for the serving stack (DESIGN.md §11).

The anytime/SLA machinery exists so the server can promise "exact, degraded
— with certified bits — or shed, never a hang and never silently wrong".
This module is the harness that *proves* it under adversity.  Four injector
families, each deterministic under a seed:

* **slow-engine stalls** — :class:`FaultyEngine` sleeps before delegating a
  dispatch with probability ``p_stall``; the straggler watchdog must flag
  them and every admitted request must still terminate;
* **dispatch exceptions** — :class:`FaultyEngine` raises
  :class:`InjectedDispatchError` with probability ``p_error``; the error
  must land on the affected tickets (never swallowed, never a hang);
* **cache poisoning** — :func:`poison_cache` plants a wrong-version entry
  (a stale engine content tag); the versioned cache key must make it
  unreachable, so the poisoned answer is *never served*;
* **snapshot swap under load** — :func:`swap_under_load` hot-swaps the
  engine while an open-loop stream runs; every response must come from a
  consistent engine version and the drain must terminate.

Run the whole suite from the command line (the CI ``anytime-smoke`` job)::

    python -m repro.serve.faults --seed 0

Exit code 0 = every property held; the printed lines are the evidence.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
import time

import numpy as np

from repro.serve.batcher import QueryProfile
from repro.serve.loadgen import (LoadReport, RetryPolicy, open_loop,
                                 sample_queries)
from repro.serve.server import (MIN_BUDGET, RequestTimeout, RowResult,
                                SearchServer, ShedError)


class InjectedDispatchError(RuntimeError):
    """The failure :class:`FaultyEngine` raises — typed so tests can tell an
    injected fault from a genuine bug."""


@dataclasses.dataclass
class FaultPlan:
    """What to inject, with what probability (rolled per dispatch, seeded)."""
    p_stall: float = 0.0        # sleep stall_ms before delegating
    stall_ms: float = 50.0
    p_error: float = 0.0        # raise InjectedDispatchError instead
    seed: int = 0


class FaultyEngine:
    """Engine proxy that injects :class:`FaultPlan` faults at ``search``.

    Everything else — config, model, df tables, content tag, cost model —
    delegates to the wrapped engine, so the server cannot tell it apart
    from a healthy one until a dispatch goes wrong.  Counters record what
    was actually injected (the suite asserts against them)."""

    def __init__(self, engine, plan: FaultPlan):
        self._engine = engine
        self._plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.n_stalls = 0
        self.n_injected_errors = 0

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_engine"), name)

    def search(self, queries, **kw):
        plan = self._plan
        roll = float(self._rng.random())
        if roll < plan.p_error:
            self.n_injected_errors += 1
            raise InjectedDispatchError(
                f"injected dispatch failure (roll={roll:.3f})")
        if roll < plan.p_error + plan.p_stall:
            self.n_stalls += 1
            time.sleep(plan.stall_ms / 1e3)
        return self._engine.search(queries, **kw)


POISON_DOC = -7     # a doc id no real engine can produce


def poison_cache(server: SearchServer, words, profile: QueryProfile,
                 *, stale_tag="stale-engine-tag") -> RowResult:
    """Plant a wrong-version cache entry for ``(words, profile)``: the row a
    server with a *different* engine content tag would have cached.  The
    server's cache keys are versioned by its live tag, so the poisoned
    entry must be unreachable — :func:`check_poison_never_served` asserts
    a subsequent search returns a real answer, not this one."""
    k = profile.k or getattr(server.engine, "config", None) and \
        server.engine.config.default_k or 10
    fake = RowResult(docs=np.full(k, POISON_DOC, np.int32),
                     scores=np.zeros(k, np.float32), n_found=k, work=0,
                     k=k, mode=profile.mode, strategy="dr",
                     measure=profile.measure)
    server.cache.put((tuple(int(w) for w in words), profile, stale_tag), fake)
    return fake


def check_poison_never_served(server: SearchServer, words,
                              profile: QueryProfile) -> None:
    poison_cache(server, words, profile)
    row = server.search(words, profile, timeout=30.0)
    if row.n_found and int(row.docs[0]) == POISON_DOC:
        raise AssertionError("poisoned cache entry was served")


def swap_under_load(server: SearchServer, next_engine, workload, *,
                    profile: QueryProfile, qps: float = 300.0,
                    seed: int = 0) -> LoadReport:
    """Hot-swap ``next_engine`` in while an open-loop stream runs.  Sheds
    during the drain are expected (that is the swap contract); hangs and
    non-shed errors are not — the returned report's accounting must close
    (ok + shed + err + timeout == submitted attempts)."""
    box = {}

    def swapper():
        time.sleep(0.05)                      # let the stream establish
        box["old"] = server.swap_engine(next_engine, drain_timeout=30.0)

    th = threading.Thread(target=swapper)
    th.start()
    rep = open_loop(server, workload, target_qps=qps, profile=profile,
                    seed=seed, timeout_s=30.0)
    th.join(timeout=30.0)
    if th.is_alive():
        raise AssertionError("swap_engine hung under load")
    if "old" not in box:
        raise AssertionError("swap_engine did not complete")
    return rep


# -- the CI suite ------------------------------------------------------------

def _build(seed: int, n_docs: int = 150):
    from repro.engine import SearchEngine
    from repro.text import corpus
    cp = corpus.make_corpus(n_docs=n_docs, mean_doc_len=60, vocab_size=500,
                            seed=seed)
    return SearchEngine.build(cp)


def run_suite(seed: int = 0, verbose: bool = True) -> list[str]:
    """Run every fault family against a real engine; returns the list of
    failures (empty = suite passed).  Each check prints one evidence line."""
    failures: list[str] = []

    def check(name: str, fn):
        t0 = time.monotonic()
        try:
            detail = fn() or ""
            if verbose:
                print(f"  ok  {name} ({time.monotonic()-t0:.2f}s) {detail}")
        except Exception as e:          # noqa: BLE001 — the suite must finish
            failures.append(f"{name}: {e}")
            if verbose:
                print(f"FAIL  {name}: {e}")

    engine = _build(seed)
    queries = sample_queries(engine, 40, seed=seed)
    profile = QueryProfile(mode="or", k=8)

    def liveness_under_stalls():
        faulty = FaultyEngine(_build(seed), FaultPlan(
            p_stall=0.3, stall_ms=30.0, p_error=0.15, seed=seed))
        srv = SearchServer(faulty, max_batch=4, max_wait_ms=0.5,
                           queue_depth=16)
        with srv:
            srv.warmup(queries[:4], profile)
            rep = open_loop(srv, queries * 2, target_qps=400.0,
                            profile=profile, seed=seed, timeout_s=30.0)
        total = rep.n_ok + rep.n_shed + rep.n_err + rep.n_timeout
        assert total == len(queries) * 2, \
            f"accounting leak: {total} != {len(queries) * 2}"
        assert rep.n_timeout == 0, f"{rep.n_timeout} requests hung"
        if faulty.n_injected_errors:
            assert rep.n_err > 0, "injected errors vanished silently"
        assert srv.n_stragglers > 0 or faulty.n_stalls == 0, \
            "watchdog saw no stragglers despite stalls"
        return (f"[{rep.n_ok} ok, {rep.n_err} err, {rep.n_shed} shed, "
                f"{faulty.n_stalls} stalls, {srv.n_stragglers} flagged]")

    def degraded_not_shed():
        slow = FaultyEngine(_build(seed), FaultPlan(
            p_stall=1.0, stall_ms=15.0, seed=seed))
        srv = SearchServer(slow, max_batch=2, max_wait_ms=0.0, queue_depth=8)
        with srv:
            srv.warmup(queries[:4], profile)
            rep = open_loop(srv, queries * 3, target_qps=2000.0,
                            profile=QueryProfile(mode="or", k=8,
                                                 sla="best_effort"),
                            seed=seed, timeout_s=30.0)
        assert rep.n_timeout == 0, f"{rep.n_timeout} requests hung"
        assert rep.n_degraded > 0, \
            "overload never engaged degraded serving (expected budget shrink)"
        degraded_budgets = {k.budget for k in getattr(
            srv.engine, "_executors", {})}
        assert any(b is not None and b >= MIN_BUDGET
                   for b in degraded_budgets), \
            f"no degraded executor ran (budgets: {degraded_budgets})"
        return (f"[{rep.n_ok} ok, {rep.n_degraded} degraded, "
                f"{rep.n_shed} shed, certified "
                f"{rep.certified_fraction:.2f}]")

    def poison_unreachable():
        srv = SearchServer(engine, max_batch=4, max_wait_ms=0.5,
                           queue_depth=16)
        with srv:
            for q in queries[:5]:
                check_poison_never_served(srv, q, profile)
        return "[5 poisoned keys, 0 served]"

    def swap_consistency():
        srv = SearchServer(engine, max_batch=4, max_wait_ms=0.5,
                           queue_depth=32)
        with srv:
            srv.warmup(queries[:4], profile)
            rep = swap_under_load(srv, _build(seed + 1), queries * 2,
                                  profile=profile, qps=500.0, seed=seed)
            assert srv.stats["swaps"] == 1
            total = rep.n_ok + rep.n_shed + rep.n_err + rep.n_timeout
            assert total == len(queries) * 2, "accounting leak across swap"
            assert rep.n_timeout == 0, f"{rep.n_timeout} requests hung"
            # post-swap sanity: the new engine answers, cache rebuilt
            row = srv.search(queries[0], profile, timeout=30.0)
            assert row.n_found >= 0
        return f"[swap ok, {rep.n_shed} shed during drain]"

    def timeout_finalized():
        stuck = FaultyEngine(_build(seed), FaultPlan(
            p_stall=1.0, stall_ms=300.0, seed=seed))
        srv = SearchServer(stuck, max_batch=1, max_wait_ms=0.0,
                           queue_depth=64)
        with srv:
            rep = open_loop(srv, queries[:8], target_qps=1000.0,
                            profile=profile, seed=seed, timeout_s=0.2,
                            retry=RetryPolicy(max_retries=2, seed=seed))
            assert rep.n_timeout > 0, "expected timeouts under 300ms stalls"
            # cancelled tickets must hold RequestTimeout, not dangle
        time.sleep(0.5)         # let late dispatches finish against cancels
        return f"[{rep.n_timeout} cancelled, none resurrected]"

    check("liveness-under-stalls+errors", liveness_under_stalls)
    check("degraded-not-shed", degraded_not_shed)
    check("cache-poison-unreachable", poison_unreachable)
    check("swap-under-load", swap_consistency)
    check("timeout-finalized", timeout_finalized)
    return failures


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    print(f"fault-injection suite (seed={args.seed})")
    failures = run_suite(seed=args.seed, verbose=not args.quiet)
    if failures:
        print(f"{len(failures)} FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("all fault-injection checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
