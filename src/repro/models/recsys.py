"""Recsys towers: FM, xDeepFM (CIN), DLRM (dot interaction), SASRec.

Common skeleton: huge sparse embedding tables -> feature interaction ->
small MLP -> CTR logit (or next-item scores for SASRec).

EmbeddingBag contract (the brief): JAX has no native EmbeddingBag — we
implement it as ``jnp.take`` + ``jax.ops.segment_sum`` (`embedding_bag`), and
single-valued Criteo-style lookups as the special case.  Table sharding:
row-sharded over ``(pod, data)`` and column-sharded over ``model`` for large
tables (DESIGN.md §4); XLA turns gathers on row-sharded tables into the
standard DLRM model-parallel exchange.

``retrieval_cand`` (score one query against 10^6 candidates) is a batched
tower evaluation; for SASRec it collapses to one matvec against the item
table and reuses the fused `kernels/topk_score` primitive.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L

# Criteo-1TB per-feature cardinalities (MLPerf DLRM reference)
CRITEO_1TB_ROWS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str                  # 'fm' | 'cin' | 'dot' | 'self-attn-seq'
    n_sparse: int = 39
    embed_dim: int = 10
    n_dense: int = 0
    table_rows: tuple[int, ...] = ()  # per-feature cardinality (len n_sparse)
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    cin_layers: tuple[int, ...] = ()
    # sasrec
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    n_items: int = 0
    dtype: Any = jnp.float32

    def rows(self) -> tuple[int, ...]:
        """Per-feature cardinalities, padded to 512-row multiples so table
        rows divide the (pod, data) mesh axes for row-sharding (hash-bucket
        semantics are unchanged — pad rows are never addressed)."""
        base = self.table_rows or tuple([1_000_000] * self.n_sparse)
        return tuple(-(-r // 512) * 512 for r in base)


# ---------------------------------------------------------------------------
# EmbeddingBag (take + segment_sum) — the JAX-native implementation
# ---------------------------------------------------------------------------

def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, offsets: jnp.ndarray,
                  n_bags: int) -> jnp.ndarray:
    """sum-mode EmbeddingBag: ids (L,) flat indices, offsets (n_bags,) starts."""
    bags = jnp.searchsorted(offsets, jnp.arange(ids.shape[0]), side="right") - 1
    vecs = jnp.take(table, ids, axis=0)
    return jax.ops.segment_sum(vecs, bags, num_segments=n_bags)


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [L.init_dense(k, i, o, dtype) for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp(ws, x, act=jax.nn.relu, final_act=False):
    for i, w in enumerate(ws):
        x = x @ w
        if i < len(ws) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# parameter init / specs
# ---------------------------------------------------------------------------

def init_params(key, cfg: RecsysConfig) -> dict:
    keys = jax.random.split(key, 8)
    p: dict = {}
    if cfg.interaction == "self-attn-seq":
        d = cfg.embed_dim
        p["item_emb"] = (jax.random.normal(keys[0], (cfg.n_items, d)) * 0.02
                         ).astype(cfg.dtype)
        p["pos_emb"] = (jax.random.normal(keys[1], (cfg.seq_len, d)) * 0.02
                        ).astype(cfg.dtype)
        blocks = []
        for b in range(cfg.n_blocks):
            kb = jax.random.split(keys[2 + b], 6)
            blocks.append({
                "wq": L.init_dense(kb[0], d, d, cfg.dtype),
                "wk": L.init_dense(kb[1], d, d, cfg.dtype),
                "wv": L.init_dense(kb[2], d, d, cfg.dtype),
                "wo": L.init_dense(kb[3], d, d, cfg.dtype),
                "ff1": L.init_dense(kb[4], d, d, cfg.dtype),
                "ff2": L.init_dense(kb[5], d, d, cfg.dtype),
                "norm1": jnp.zeros((d,), cfg.dtype),
                "norm2": jnp.zeros((d,), cfg.dtype),
            })
        p["blocks"] = blocks
        return p

    # tabular towers: one table per sparse feature
    tkeys = jax.random.split(keys[0], cfg.n_sparse)
    p["tables"] = [
        (jax.random.normal(k, (rows, cfg.embed_dim)) * (1.0 / cfg.embed_dim) ** 0.5
         ).astype(cfg.dtype)
        for k, rows in zip(tkeys, cfg.rows())]
    if cfg.interaction == "fm":
        lkeys = jax.random.split(keys[1], cfg.n_sparse)
        p["linear"] = [(jax.random.normal(k, (rows, 1)) * 0.01).astype(cfg.dtype)
                       for k, rows in zip(lkeys, cfg.rows())]
        p["bias"] = jnp.zeros((), cfg.dtype)
    if cfg.bot_mlp:
        p["bot"] = _mlp_init(keys[2], (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype)
    if cfg.interaction == "cin":
        dims = (cfg.n_sparse,) + cfg.cin_layers
        ckeys = jax.random.split(keys[3], len(cfg.cin_layers))
        p["cin"] = [
            (jax.random.normal(k, (dims[i + 1], dims[i], cfg.n_sparse))
             * (1.0 / (dims[i] * cfg.n_sparse)) ** 0.5).astype(cfg.dtype)
            for i, k in enumerate(ckeys)]
        # DNN branch of xDeepFM
        p["dnn"] = _mlp_init(keys[4], (cfg.n_sparse * cfg.embed_dim, 400, 400),
                             cfg.dtype)
        p["out"] = L.init_dense(keys[5], sum(cfg.cin_layers) + 400 + 1, 1, cfg.dtype)
        p["linear_w"] = _mlp_init(keys[6], (cfg.n_sparse * cfg.embed_dim, 1), cfg.dtype)
    if cfg.interaction == "dot":
        n_f = cfg.n_sparse + 1
        n_inter = n_f * (n_f - 1) // 2
        p["top"] = _mlp_init(keys[3], (cfg.bot_mlp[-1] + n_inter,) + cfg.top_mlp,
                             cfg.dtype)
    return p


def param_specs(cfg: RecsysConfig, rules: L.MeshRules):
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if "tables" in keys or "linear" in keys or "item_emb" in keys:
            if leaf.shape[0] >= 100_000:      # big tables: row-shard
                return rules.spec("rows", None)
            return jax.sharding.PartitionSpec()
        return jax.sharding.PartitionSpec()   # towers are tiny: replicate

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


# ---------------------------------------------------------------------------
# interactions
# ---------------------------------------------------------------------------

def fm_interaction(v: jnp.ndarray) -> jnp.ndarray:
    """v (B, F, d): 0.5 * ((sum_i v_i)^2 - sum_i v_i^2), summed over d.
    The O(F d) sum-square trick (Rendle ICDM'10)."""
    s = jnp.sum(v, axis=1)
    s2 = jnp.sum(v * v, axis=1)
    return 0.5 * jnp.sum(s * s - s2, axis=-1, keepdims=True)


def dot_interaction(v: jnp.ndarray) -> jnp.ndarray:
    """v (B, F, d): all pairwise dots, lower triangle flattened (DLRM)."""
    g = jnp.einsum("bfd,bgd->bfg", v, v)
    f = v.shape[1]
    iu, ju = jnp.tril_indices(f, k=-1)
    return g[:, iu, ju]


def cin_layers_apply(ws, x0: jnp.ndarray) -> jnp.ndarray:
    """Compressed Interaction Network (xDeepFM eq. 6): x^{k+1}_h = sum_{ij}
    W_{h,i,j} (x^k_i * x^0_j); sum-pool each level over d."""
    xk = x0
    pooled = []
    for w in ws:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,nhm->bnd", z, w)
        pooled.append(jnp.sum(xk, axis=-1))
    return jnp.concatenate(pooled, axis=-1)    # (B, sum(H_k))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _lookup(tables: Sequence[jnp.ndarray], sparse_ids: jnp.ndarray,
            rules: L.MeshRules) -> jnp.ndarray:
    """sparse_ids (B, F) -> (B, F, d).  One gather per table (sizes differ)."""
    outs = []
    for f, t in enumerate(tables):
        ids = jnp.clip(sparse_ids[:, f], 0, t.shape[0] - 1)
        outs.append(jnp.take(t, ids, axis=0))
    v = jnp.stack(outs, axis=1)
    return L.constrain(v, rules, "batch", None, None)


def forward(params: dict, batch: dict, cfg: RecsysConfig,
            rules: L.MeshRules) -> jnp.ndarray:
    """Returns CTR logits (B,) for tabular towers, or (B, S, d) hidden states
    for SASRec (scored against item embeddings by the callers)."""
    if cfg.interaction == "self-attn-seq":
        return _sasrec_forward(params, batch["seq"], cfg, rules)

    v = _lookup(params["tables"], batch["sparse"], rules)      # (B, F, d)
    if cfg.interaction == "fm":
        lin = sum(jnp.take(t, jnp.clip(batch["sparse"][:, f], 0, t.shape[0] - 1),
                           axis=0)
                  for f, t in enumerate(params["linear"]))     # (B, 1)
        return (fm_interaction(v) + lin + params["bias"])[:, 0]
    if cfg.interaction == "cin":
        cin_out = cin_layers_apply(params["cin"], v)
        flat = v.reshape(v.shape[0], -1)
        dnn_out = _mlp(params["dnn"], flat, final_act=True)
        lin = _mlp(params["linear_w"], flat)
        out = jnp.concatenate([cin_out, dnn_out, lin], axis=-1)
        return _mlp([params["out"]], out)[:, 0]
    if cfg.interaction == "dot":
        dense = _mlp(params["bot"], batch["dense"].astype(cfg.dtype),
                     final_act=True)                           # (B, d)
        feats = jnp.concatenate([dense[:, None, :], v], axis=1)
        inter = dot_interaction(feats)
        top_in = jnp.concatenate([dense, inter], axis=-1)
        return _mlp(params["top"], top_in)[:, 0]
    raise ValueError(cfg.interaction)


def _sasrec_forward(params, seq, cfg: RecsysConfig, rules: L.MeshRules):
    """seq (B, S) item ids -> (B, S, d) hidden states (causal self-attn)."""
    B, S = seq.shape
    d = cfg.embed_dim
    h = jnp.take(params["item_emb"], jnp.clip(seq, 0, cfg.n_items - 1), axis=0)
    h = h * jnp.sqrt(float(d)).astype(h.dtype) + params["pos_emb"][None, :S]
    mask = jnp.tril(jnp.ones((S, S), bool))
    for blk in params["blocks"]:
        q = L.rms_norm(h, blk["norm1"])
        att = jnp.einsum("bqd,bkd->bqk", q @ blk["wq"], q @ blk["wk"])
        att = att / jnp.sqrt(float(d))
        att = jnp.where(mask[None], att.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(att, axis=-1).astype(h.dtype)
        h = h + (jnp.einsum("bqk,bkd->bqd", w, q @ blk["wv"]) @ blk["wo"])
        f = L.rms_norm(h, blk["norm2"])
        h = h + jax.nn.relu(f @ blk["ff1"]) @ blk["ff2"]
    return h


def loss_fn(params, batch, cfg: RecsysConfig, rules: L.MeshRules):
    if cfg.interaction == "self-attn-seq":
        h = _sasrec_forward(params, batch["seq"], cfg, rules)     # (B, S, d)
        pos_v = jnp.take(params["item_emb"],
                         jnp.clip(batch["pos"], 0, cfg.n_items - 1), axis=0)
        neg_v = jnp.take(params["item_emb"],
                         jnp.clip(batch["neg"], 0, cfg.n_items - 1), axis=0)
        s_pos = jnp.sum(h * pos_v, axis=-1).astype(jnp.float32)
        s_neg = jnp.sum(h * neg_v, axis=-1).astype(jnp.float32)
        m = (batch["pos"] > 0).astype(jnp.float32)
        # SASRec BCE: positive vs one sampled negative per step
        nll = -(jax.nn.log_sigmoid(s_pos) + jax.nn.log_sigmoid(-s_neg)) * m
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(m), 1.0)
        return loss, {"nll": loss}
    logits = forward(params, batch, cfg, rules).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(-(y * jax.nn.log_sigmoid(logits)
                      + (1 - y) * jax.nn.log_sigmoid(-logits)))
    return loss, {"nll": loss}


def serve(params, batch, cfg: RecsysConfig, rules: L.MeshRules):
    """Online/offline scoring: sigmoid CTR (tabular) / next-item hidden (seq)."""
    if cfg.interaction == "self-attn-seq":
        h = _sasrec_forward(params, batch["seq"], cfg, rules)
        return h[:, -1, :]                    # (B, d) user state
    return jax.nn.sigmoid(forward(params, batch, cfg, rules))


def retrieval_scores(params, batch, cfg: RecsysConfig, rules: L.MeshRules,
                     k: int = 100):
    """Score 1 query against n_candidates, return top-k (ANN-free exact).

    SASRec: one matvec of the user state against candidate item embeddings.
    Tabular: batched tower evaluation with the candidate id substituted into
    sparse slot 0 (the item slot), user features broadcast.
    """
    if cfg.interaction == "self-attn-seq":
        h = _sasrec_forward(params, batch["seq"], cfg, rules)[:, -1, :]  # (1, d)
        cands = jnp.take(params["item_emb"],
                         jnp.clip(batch["candidates"], 0, cfg.n_items - 1), axis=0)
        scores = (cands @ h[0]).astype(jnp.float32)
        return jax.lax.top_k(scores, k)
    C = batch["candidates"].shape[0]
    sparse = jnp.broadcast_to(batch["sparse"], (C, cfg.n_sparse)).at[:, 0].set(
        batch["candidates"])
    b = {"sparse": sparse}
    if cfg.n_dense and "dense" in batch:
        b["dense"] = jnp.broadcast_to(batch["dense"], (C, cfg.n_dense))
    scores = forward(params, b, cfg, rules).astype(jnp.float32)
    return jax.lax.top_k(scores, k)
