"""Decoder-only LM: GQA / MoE / alternating attention patterns, scan-stacked.

The repeating unit is the *layer group* = ``cfg.pattern`` (e.g. Gemma-2:
('local','global'); Llama-4: ('chunked','chunked','chunked','global') with
NoPE on the global layers).  Parameters are stacked per group position with a
leading (n_groups,) axis and the stack is driven by one ``lax.scan`` — one
trace per group position regardless of depth, which keeps HLO size and compile
time flat for 94-layer configs and gives remat a natural boundary.

Three entry points per the dry-run contract:
  train_step(params, opt_state, batch, ...)      (train_* shapes)
  prefill(params, tokens)                        (prefill_* shapes)
  decode_step(params, caches, tokens, cache_len) (decode_* / long_* shapes)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None            # default d_model // n_heads
    pattern: tuple[str, ...] = ("global",)
    use_rope_pattern: tuple[bool, ...] = (True,)
    window: int = 0
    attn_softcap: float | None = None
    final_softcap: float | None = None
    post_norms: bool = False             # Gemma-2 post-block norms
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: M.MoEConfig | None = None
    dtype: Any = jnp.bfloat16
    remat: bool = True
    aux_loss_weight: float = 0.01

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0
        return self.n_layers // len(self.pattern)

    def attn_cfg(self) -> A.AttnConfig:
        return A.AttnConfig(n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                            d_head=self.head_dim, qk_norm=self.qk_norm,
                            softcap=self.attn_softcap, rope_theta=self.rope_theta,
                            window=self.window)

    def param_count(self) -> int:
        """Analytic parameter count (roofline MODEL_FLOPS uses this)."""
        D, V, Dh = self.d_model, self.vocab, self.head_dim
        attn = D * Dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe:
            ffn = (self.moe.n_experts * 3 * D * self.moe.d_ff
                   + D * self.moe.n_experts
                   + (3 * D * self.moe.d_ff * self.moe.n_shared))
        else:
            ffn = 3 * D * self.d_ff
        return self.n_layers * (attn + ffn) + 2 * V * D

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D FLOPs convention)."""
        if not self.moe:
            return self.param_count()
        D, V = self.d_model, self.vocab
        Dh = self.head_dim
        attn = D * Dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = (self.moe.top_k + self.moe.n_shared) * 3 * D * self.moe.d_ff
        return self.n_layers * (attn + ffn) + 2 * V * D


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _init_block(key, cfg: LMConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    p = {
        "attn": A.init_attn(ka, cfg.d_model, cfg.attn_cfg(), dtype),
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.post_norms:
        p["norm1_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["norm2_post"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.moe:
        p["moe"] = M.init_moe(kf, cfg.d_model, cfg.moe, dtype)
    else:
        ks = jax.random.split(kf, 3)
        p["mlp"] = {
            "wi_gate": L.init_dense(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "wi_up": L.init_dense(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "wo": L.init_dense(ks[2], cfg.d_ff, cfg.d_model, dtype),
        }
    return p


def init_params(key, cfg: LMConfig) -> dict:
    dtype = cfg.dtype
    keys = jax.random.split(key, 3 + len(cfg.pattern))
    stacked = []
    for i in range(len(cfg.pattern)):
        gkeys = jax.random.split(keys[i], cfg.n_groups)
        stacked.append(jax.vmap(lambda k: _init_block(k, cfg, dtype))(gkeys))
    emb = (jax.random.normal(keys[-3], (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype)
    head = (jax.random.normal(keys[-2], (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype)
    return {
        "embed": emb,
        "blocks": stacked,                  # list over group positions
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": head,                    # (V, D), used transposed
    }


def param_specs(cfg: LMConfig, rules: L.MeshRules):
    """PartitionSpec pytree matching init_params (FSDP+TP; DESIGN.md §4)."""
    def attn_spec():
        s = {"wq": rules.spec("embed", "heads"), "wk": rules.spec("embed", "heads"),
             "wv": rules.spec("embed", "heads"), "wo": rules.spec("heads", "embed")}
        if cfg.qk_norm:
            s["q_norm"] = rules.spec(None)
            s["k_norm"] = rules.spec(None)
        return s

    def block_spec():
        p = {"attn": attn_spec(),
             "norm1": rules.spec(None), "norm2": rules.spec(None)}
        if cfg.post_norms:
            p["norm1_post"] = rules.spec(None)
            p["norm2_post"] = rules.spec(None)
        if cfg.moe:
            p["moe"] = {
                "router": rules.spec("embed", "experts"),
                "wi_gate": rules.spec("experts", "batch", None),
                "wi_up": rules.spec("experts", "batch", None),
                "wo": rules.spec("experts", None, "batch"),
            }
            if cfg.moe.n_shared:
                p["moe"]["shared"] = {
                    "wi_gate": rules.spec("embed", "mlp"),
                    "wi_up": rules.spec("embed", "mlp"),
                    "wo": rules.spec("mlp", "embed"),
                }
        else:
            p["mlp"] = {"wi_gate": rules.spec("embed", "mlp"),
                        "wi_up": rules.spec("embed", "mlp"),
                        "wo": rules.spec("mlp", "embed")}
        return p

    def stack(spec):
        # prepend the scanned (n_groups,) axis
        return jax.tree.map(lambda s: jax.sharding.PartitionSpec(None, *s), spec)

    return {
        "embed": rules.spec("vocab", "embed"),
        "blocks": [stack(block_spec()) for _ in cfg.pattern],
        "final_norm": rules.spec(None),
        "lm_head": rules.spec("vocab", "embed"),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(p: dict, x: jnp.ndarray, cfg: LMConfig, pattern: str,
                 use_rope: bool, rules: L.MeshRules,
                 kv_cache=None, cache_len=None):
    pat_id = jnp.int32(A.PATTERNS.index(pattern))
    h = L.rms_norm(x, p["norm1"])
    attn_out, new_kv = A.attend(p["attn"], h, cfg.attn_cfg(), pat_id,
                                rules=rules, use_rope=use_rope,
                                kv_cache=kv_cache, cache_len=cache_len)
    if cfg.post_norms:
        attn_out = L.rms_norm(attn_out, p["norm1_post"])
    x = x + attn_out
    h = L.rms_norm(x, p["norm2"])
    aux = jnp.float32(0.0)
    if cfg.moe:
        B, S, D = h.shape
        out, aux = M.moe_apply(p["moe"], h.reshape(B * S, D), cfg.moe, rules)
        out = out.reshape(B, S, D)
    else:
        out = L.mlp_apply(p["mlp"], h)
    if cfg.post_norms:
        out = L.rms_norm(out, p["norm2_post"])
    return x + out, new_kv, aux


def forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
            rules: L.MeshRules, collect_cache: bool = False):
    """tokens (B, S) -> logits (B, S, V) [+ caches].  Scan over layer groups."""
    x = params["embed"][tokens].astype(cfg.dtype) * jnp.sqrt(float(cfg.d_model)).astype(cfg.dtype)
    x = L.constrain(x, rules, "batch", "seq", "embed")

    def group_body(carry, group_params):
        x, aux = carry
        # pin the carry's sharding each group: scan transposition otherwise
        # loses it in the backward pass (replicated cotangents)
        x = L.constrain(x, rules, "batch", "seq", "embed")
        caches = []
        for i, pat in enumerate(cfg.pattern):
            x, kv, a = _block_apply(group_params[i], x, cfg, pat,
                                    cfg.use_rope_pattern[i], rules)
            aux = aux + a
            if collect_cache:
                caches.append(kv)
        return (x, aux), (tuple(caches) if collect_cache else None)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = L.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].T.astype(cfg.dtype)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    # vocab-parallel logits: S deliberately unsharded here so the constraint
    # stays valid under sequence-parallel rules (seq and vocab both map to
    # 'model'; a (batch, seq, vocab) spec would be dropped as duplicate and
    # leave 12+ GB/chip of replicated fp32 logits — §Perf hillclimb 2).
    logits = L.constrain(logits, rules, "batch", None, "vocab")
    return (logits, aux, caches) if collect_cache else (logits, aux)


def loss_fn(params, batch, cfg: LMConfig, rules: L.MeshRules):
    logits, aux = forward(params, batch["tokens"], cfg, rules)
    nll = L.cross_entropy(logits, batch["labels"])
    return nll + cfg.aux_loss_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_shapes(cfg: LMConfig, batch: int, max_len: int) -> list:
    """Per group position: (n_groups, B, S_kv, KV, Dh) k/v pairs.  Local and
    chunked layers get window-sized ring buffers — the sub-quadratic memory
    path for long_500k (DESIGN.md §5)."""
    out = []
    for pat in cfg.pattern:
        s_kv = max_len if pat == "global" or cfg.window == 0 else min(cfg.window, max_len)
        shp = (cfg.n_groups, batch, s_kv, cfg.n_kv_heads, cfg.head_dim)
        out.append((jax.ShapeDtypeStruct(shp, cfg.dtype),
                    jax.ShapeDtypeStruct(shp, cfg.dtype)))
    return out


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> list:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def decode_step(params: dict, caches: list, tokens: jnp.ndarray,
                cache_len: jnp.ndarray, cfg: LMConfig, rules: L.MeshRules):
    """One decode step: tokens (B,) -> logits (B, V), updated caches."""
    x = params["embed"][tokens[:, None]].astype(cfg.dtype) * jnp.sqrt(float(cfg.d_model)).astype(cfg.dtype)

    def group_body(x, scanned):
        group_params, group_caches = scanned
        new_caches = []
        for i, pat in enumerate(cfg.pattern):
            x, kv, _ = _block_apply(group_params[i], x, cfg, pat,
                                    cfg.use_rope_pattern[i], rules,
                                    kv_cache=group_caches[i], cache_len=cache_len)
            new_caches.append(kv)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(group_body, x, (params["blocks"], caches))
    x = L.rms_norm(x, params["final_norm"])
    logits = x[:, 0, :] @ params["lm_head"].T.astype(cfg.dtype)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, list(new_caches)


def prefill(params: dict, tokens: jnp.ndarray, cfg: LMConfig, rules: L.MeshRules):
    """Prefill: full forward returning logits + caches for subsequent decode."""
    logits, _, caches = forward(params, tokens, cfg, rules, collect_cache=True)
    return logits, caches
