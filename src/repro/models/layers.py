"""Shared neural layers (pure functions over param pytrees) + sharding rules.

Sharding follows the logical-axis-rules pattern: every parameter/activation
dimension is tagged with a logical name; ``MeshRules`` maps logical names to
mesh axes (DESIGN.md §4).  ``logical_to_spec`` produces PartitionSpecs for
pjit in_shardings and ``constrain`` applies in-function constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# logical axis rules
# ---------------------------------------------------------------------------

#: default production rules: batch over (pod, data); model dims over model.
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,              # sequence kept unsharded for training activations
    "kv_seq": "model",        # decode: split-KV over model axis (flash-decode)
    "heads": "model",
    "kv_heads": None,         # GQA kv counts (4-8) don't divide model=16; replicate
    "embed": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": ("pod", "data"),  # shard the MoE dispatch buffers
    "tokens_flat": ("pod", "data"),      # flattened (B*S,) routing arrays
    "rows": ("pod", "data"),  # embedding-table rows (recsys)
    "table_dim": "model",
    "edges": ("pod", "data"), # GNN edge lists
    "nodes": ("pod", "data"),
    "stack": None,            # scanned layer stack
}


@dataclasses.dataclass(frozen=True)
class MeshRules:
    rules: tuple[tuple[str, Any], ...]

    @classmethod
    def make(cls, overrides: dict[str, Any] | None = None) -> "MeshRules":
        r = dict(DEFAULT_RULES)
        if overrides:
            r.update(overrides)
        return cls(tuple(sorted(r.items())))

    def spec(self, *logical: str | None) -> P:
        d = dict(self.rules)
        return P(*[d.get(ax) if ax is not None else None for ax in logical])


def constrain(x: jnp.ndarray, rules: MeshRules, *logical: str | None) -> jnp.ndarray:
    """with_sharding_constraint by logical names (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(*logical))
    except Exception:   # no mesh / axis absent / spec invalid for this shape
        return x


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embeddings. x: (..., seq, heads, d_head); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., s, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def mlp_apply(params: dict, x: jnp.ndarray, act=jax.nn.silu,
              gated: bool = True) -> jnp.ndarray:
    """SwiGLU (gated=True) or plain MLP."""
    if gated:
        h = act(x @ params["wi_gate"]) * (x @ params["wi_up"])
    else:
        h = act(x @ params["wi_up"])
    return h @ params["wo"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token NLL; logits (..., V) any float dtype, labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
