"""Mixture-of-Experts FFN: top-k token-choice routing, sort-based dispatch.

Dispatch strategy (DESIGN.md §4): the classical one-hot einsum dispatch
materializes a (T, E, C) tensor — quadratic in tokens at large T.  We instead
sort the (T·k) routed copies by expert id, compute each copy's slot inside its
expert via exclusive-cumsum arithmetic, and scatter into a capacity-bounded
(E, C, D) buffer (overflow drops, GShard-style).  Expert FLOPs are then two
MXU-shaped batched einsums.  Under the production mesh the buffer is sharded
over ``experts -> model`` and tokens over ``batch -> (pod, data)``; XLA SPMD
lowers the scatter/gather pair to the expert-parallel all-to-all.

top-k gates are softmax-renormalized over the selected experts (Qwen3-MoE's
``norm_topk_prob``); ``n_shared`` adds always-on shared experts (Llama-4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    n_shared: int = 0              # always-on shared experts (fused as one MLP)
    capacity_factor: float = 1.25


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    scale = (1.0 / d_model) ** 0.5
    p = {
        "router": L.init_dense(ks[0], d_model, E, jnp.float32),  # fp32 router
        "wi_gate": (jax.random.normal(ks[1], (E, d_model, F)) * scale).astype(dtype),
        "wi_up": (jax.random.normal(ks[2], (E, d_model, F)) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, F, d_model)) * (1.0 / F) ** 0.5).astype(dtype),
    }
    if cfg.n_shared:
        Fs = F * cfg.n_shared
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": L.init_dense(kss[0], d_model, Fs, dtype),
            "wi_up": L.init_dense(kss[1], d_model, Fs, dtype),
            "wo": L.init_dense(kss[2], Fs, d_model, dtype),
        }
    return p


def _pinned_dispatch_ops(rules: L.MeshRules, E: int, C: int, T: int,
                         D: int, dtype):
    """Gather/scatter for MoE dispatch with **pinned cotangent shardings**.

    XLA's backward sharding propagation fails through the scatter fusions the
    dispatch produces: the (T*K, D) cotangents materialize fully replicated
    (measured: repeated 128 GiB f32/u32 all-reduce/all-gather pairs on
    qwen3-moe train_4k — EXPERIMENTS.md §Perf iteration 2).  custom_vjp lets
    us constrain both the primal and the cotangent of every gather/scatter.
    """

    @jax.custom_vjp
    def token_gather(x, tok):                       # (T,D),(TK,) -> (TK,D)
        return x[tok]

    def token_gather_fwd(x, tok):
        y = L.constrain(x[tok], rules, "tokens_flat", None)
        return y, (tok,)

    def token_gather_bwd(res, g):
        (tok,) = res
        g = L.constrain(g.astype(dtype), rules, "tokens_flat", None)
        gx = jnp.zeros((T, D), dtype).at[tok].add(g)
        return L.constrain(gx, rules, "tokens_flat", None), None

    token_gather.defvjp(token_gather_fwd, token_gather_bwd)

    @jax.custom_vjp
    def buf_scatter(vals, e, slot):                 # (TK,D) -> (E,C,D)
        buf = jnp.zeros((E, C, D), dtype)
        return buf.at[e, slot].set(vals, mode="drop")

    def buf_scatter_fwd(vals, e, slot):
        buf = jnp.zeros((E, C, D), dtype)
        buf = buf.at[e, slot].set(vals, mode="drop")
        return (L.constrain(buf, rules, "experts", "expert_capacity", None),
                (e, slot))

    def buf_scatter_bwd(res, g):
        e, slot = res
        g = L.constrain(g.astype(dtype), rules, "experts", "expert_capacity", None)
        gv = g.at[e, slot].get(mode="fill", fill_value=0)
        return L.constrain(gv, rules, "tokens_flat", None), None, None

    buf_scatter.defvjp(buf_scatter_fwd, buf_scatter_bwd)

    @jax.custom_vjp
    def buf_gather(buf, e, slot):                   # (E,C,D) -> (TK,D)
        return buf.at[e, slot].get(mode="fill", fill_value=0)

    def buf_gather_fwd(buf, e, slot):
        y = buf.at[e, slot].get(mode="fill", fill_value=0)
        return (L.constrain(y, rules, "tokens_flat", None), (e, slot))

    def buf_gather_bwd(res, g):
        e, slot = res
        g = L.constrain(g.astype(dtype), rules, "tokens_flat", None)
        gb = jnp.zeros((E, C, D), dtype).at[e, slot].add(g, mode="drop")
        return (L.constrain(gb, rules, "experts", "expert_capacity", None),
                None, None)

    buf_gather.defvjp(buf_gather_fwd, buf_gather_bwd)

    @jax.custom_vjp
    def token_combine(y_weighted, tok):             # (TK,D) -> (T,D)
        return jnp.zeros((T, D), dtype).at[tok].add(y_weighted)

    def token_combine_fwd(y_weighted, tok):
        out = jnp.zeros((T, D), dtype).at[tok].add(y_weighted)
        return (L.constrain(out, rules, "tokens_flat", None), (tok,))

    def token_combine_bwd(res, g):
        (tok,) = res
        g = L.constrain(g.astype(dtype), rules, "tokens_flat", None)
        gy = L.constrain(g[tok], rules, "tokens_flat", None)
        return gy, None

    token_combine.defvjp(token_combine_fwd, token_combine_bwd)
    return token_gather, buf_scatter, buf_gather, token_combine


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig,
              rules: L.MeshRules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, D) flattened tokens.  Returns (out (T, D), aux_loss ()).

    Two dispatch paths:
    * under a mesh with a 'model' axis: **shard_map expert parallelism**
      (`_moe_ep_shardmap`) — per-shard local sort/scatter (zero SPMD scatter
      collectives), expert weights sharded over 'model', one psum of the
      (T_local, D) partial outputs per layer.  This replaced the pjit global
      dispatch after EXPERIMENTS.md §Perf iterations 1-2 measured XLA
      replicating (T*K, D) dispatch cotangents (128 GiB collectives).
    * otherwise (CPU tests, single device): the pjit sort-based dispatch.
    """
    mesh = _current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        tok_div = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                tok_div *= mesh.shape[a]
        if (x.shape[0] % tok_div == 0
                and cfg.n_experts % mesh.shape["model"] == 0):
            return _moe_ep_shardmap(params, x, cfg, rules, mesh)
    return _moe_dense_dispatch(params, x, cfg, rules)


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _router(params, x, cfg: MoEConfig):
    """Shared routing math: returns (gates (T,K), eidx (T,K), aux ())."""
    E, K = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    return gates, eidx, E * jnp.sum(me * ce)


def _moe_ep_shardmap(params, x, cfg: MoEConfig, rules: L.MeshRules, mesh):
    """Expert-parallel MoE: tokens replicated over 'model', experts sharded.

    Each model-shard owns E_local = E/M experts; it dispatches the tokens of
    its (pod, data) block routed to its experts with purely LOCAL sort +
    scatter (collision-free), runs the expert FFN, scatters results back and
    psums partial outputs over 'model' (each token touched K experts spread
    across shards).  Collectives per layer: one psum of (T_local, D) — the
    minimum for replicated-activation expert parallelism.
    """
    from jax.sharding import PartitionSpec as P
    E, K = cfg.n_experts, cfg.top_k
    M = mesh.shape["model"]
    assert E % M == 0, (E, M)
    E_loc = E // M
    token_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok_spec = P(token_axes if len(token_axes) > 1 else
                 (token_axes[0] if token_axes else None), None)

    def local(px, x_loc):
        T_loc, D = x_loc.shape
        C = max(8, int(T_loc * K * cfg.capacity_factor / E_loc / M) * 2)
        gates, eidx, aux = _router(px, x_loc, cfg)
        m = jax.lax.axis_index("model")
        e_flat = eidx.reshape(-1).astype(jnp.int32)
        g_flat = gates.reshape(-1).astype(x_loc.dtype)
        tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), K)
        # route only this shard's experts; everything else -> overflow bucket
        mine = (e_flat // E_loc) == m
        e_loc = jnp.where(mine, e_flat - m * E_loc, E_loc)
        order = jnp.argsort(e_loc)
        e_s, tok_s, g_s = e_loc[order], tok[order], g_flat[order]
        counts = jnp.zeros((E_loc + 1,), jnp.int32).at[e_loc].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        slot = jnp.arange(T_loc * K, dtype=jnp.int32) - starts[e_s]
        keep = e_s < E_loc
        buf = jnp.zeros((E_loc, C, D), x_loc.dtype)
        buf = buf.at[jnp.where(keep, e_s, E_loc), slot].set(
            x_loc[tok_s], mode="drop")

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, px["wi_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, px["wi_up"])
        out_buf = jnp.einsum("ecf,efd->ecd", h, px["wo"])

        y = out_buf.at[jnp.where(keep, e_s, E_loc), slot].get(
            mode="fill", fill_value=0)                      # (T_loc*K, D)
        y = y * (g_s * keep.astype(g_s.dtype))[:, None]
        out = jnp.zeros((T_loc, D), x_loc.dtype).at[tok_s].add(y)
        out = jax.lax.psum(out, "model")                    # combine K experts
        if cfg.n_shared:
            # shared expert runs on the first model shard only (its weights
            # are replicated; psum above already merged routed experts)
            shared = L.mlp_apply(px["shared"], x_loc)
            out = out + shared
        for ax in token_axes:
            aux = jax.lax.pmean(aux, ax)
        return out, jax.lax.pmean(aux, "model")

    expert_specs = {
        "router": P(None, None),
        "wi_gate": P("model", None, None),
        "wi_up": P("model", None, None),
        "wo": P("model", None, None),
    }
    if cfg.n_shared:
        expert_specs["shared"] = {"wi_gate": P(None, None),
                                  "wi_up": P(None, None),
                                  "wo": P(None, None)}
    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(expert_specs, tok_spec),
                       out_specs=(tok_spec, P()),
                       check_vma=False)
    return fn(params, x)


def _moe_dense_dispatch(params, x, cfg: MoEConfig, rules: L.MeshRules):
    """pjit global sort-based dispatch (single-device / no-'model'-axis path)."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    capacity = max(8, int(T * K * cfg.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                        # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------------
    # Every (T*K)-long routing array and the (E, C, D) buffers carry explicit
    # sharding constraints: without them XLA materializes replicated copies of
    # the dispatched activations (measured +47 GB peak / +30 s memory term on
    # qwen3-moe train_4k — EXPERIMENTS.md §Perf iteration 1).
    x = L.constrain(x, rules, "tokens_flat", None)
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)      # (T*K,)
    e_flat = eidx.reshape(-1).astype(jnp.int32)
    g_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sorted = L.constrain(e_flat[order], rules, "tokens_flat")
    tok_sorted = L.constrain(tok_ids[order], rules, "tokens_flat")
    g_sorted = L.constrain(g_flat[order], rules, "tokens_flat")

    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(T * K, dtype=jnp.int32) - starts[e_sorted] # pos within expert

    token_gather, buf_scatter, buf_gather, token_combine = \
        _pinned_dispatch_ops(rules, E, capacity, T, D, x.dtype)
    buf = buf_scatter(token_gather(x, tok_sorted), e_sorted, slot)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])
    out_buf = L.constrain(out_buf, rules, "experts", "expert_capacity", None)

    y = buf_gather(out_buf, e_sorted, slot)                       # (T*K, D)
    out = token_combine(y * g_sorted[:, None].astype(x.dtype), tok_sorted)

    if cfg.n_shared:
        out = out + L.mlp_apply(params["shared"], x)
    return out, aux
