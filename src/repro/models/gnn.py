"""EGNN — E(n)-Equivariant Graph Neural Network [Satorras et al. 2102.09844].

Message passing over an explicit edge list (JAX has no CSR SpMM; the
brief's contract): messages are computed per edge and aggregated with
``jax.ops.segment_sum`` — the scatter formulation that shards cleanly with
edges over ``(pod, data)``.

Layer (paper eqs. 3-6):
  m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
  x_i'  = x_i + C * sum_j (x_i - x_j) * phi_x(m_ij)      (equivariant update)
  h_i'  = phi_h(h_i, sum_j m_ij)

Node-classification head for the citation/products tasks; graph-level
readout (sum pool) for the `molecule` shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433
    d_coord: int = 3
    n_classes: int = 40
    graph_readout: bool = False       # molecule shape: sum-pool + graph head
    n_graphs: int = 128               # graphs per batch when graph_readout
    dtype: Any = jnp.float32


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [L.init_dense(k, i, o, dtype) for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp(ws, x, act=jax.nn.silu, final_act=False):
    for i, w in enumerate(ws):
        x = x @ w
        if i < len(ws) - 1 or final_act:
            x = act(x)
    return x


def init_params(key, cfg: EGNNConfig) -> dict:
    H = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    layers = []
    for l in range(cfg.n_layers):
        ke, kx, kh = keys[3 * l:3 * l + 3]
        layers.append({
            "phi_e": _mlp_init(ke, (2 * H + 1, H, H), cfg.dtype),
            "phi_x": _mlp_init(kx, (H, H, 1), cfg.dtype),
            "phi_h": _mlp_init(kh, (2 * H, H, H), cfg.dtype),
        })
    return {
        "embed_in": L.init_dense(keys[-2], cfg.d_feat, H, cfg.dtype),
        "layers": layers,
        "head": L.init_dense(keys[-1], H, cfg.n_classes, cfg.dtype),
    }


def egnn_layer(p: dict, h, x, edges, n_nodes: int, rules: L.MeshRules):
    """h (N, H) features, x (N, D) coordinates, edges (E, 2) [src, dst]."""
    src, dst = edges[:, 0], edges[:, 1]
    h_i, h_j = h[dst], h[src]
    x_i, x_j = x[dst], x[src]
    diff = x_i - x_j
    d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
    m = _mlp(p["phi_e"], jnp.concatenate([h_i, h_j, d2], axis=-1), final_act=True)
    m = L.constrain(m, rules, "edges", None)

    # equivariant coordinate update (normalized by distance, +1 for stability)
    w = _mlp(p["phi_x"], m)
    upd = diff / (jnp.sqrt(d2) + 1.0) * w
    x_new = x + jax.ops.segment_sum(upd, dst, num_segments=n_nodes)

    agg = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
    h_new = h + _mlp(p["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return h_new, x_new


def forward(params: dict, batch: dict, cfg: EGNNConfig, rules: L.MeshRules):
    """batch: feats (N, F), coords (N, D), edges (E, 2), [graph_ids (N,)]."""
    n_nodes = batch["feats"].shape[0]
    h = batch["feats"].astype(cfg.dtype) @ params["embed_in"]
    x = batch["coords"].astype(cfg.dtype)
    h = L.constrain(h, rules, "nodes", None)
    for p in params["layers"]:
        h, x = egnn_layer(p, h, x, batch["edges"], n_nodes, rules)
    if cfg.graph_readout:
        pooled = jax.ops.segment_sum(h, batch["graph_ids"],
                                     num_segments=cfg.n_graphs)
        return pooled @ params["head"]
    return h @ params["head"]


def loss_fn(params, batch, cfg: EGNNConfig, rules: L.MeshRules):
    logits = forward(params, batch, cfg, rules)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        nll = jnp.mean(nll)
    return nll, {"nll": nll}


def param_specs(cfg: EGNNConfig, rules: L.MeshRules):
    """EGNN params are tiny (d_hidden=64): replicate everything."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree.map(lambda _: jax.sharding.PartitionSpec(), shapes)
