"""Attention: GQA with per-layer pattern (global / sliding-window / chunked),
optional qk-norm (Qwen3) and attention-logit softcap (Gemma-2), RoPE or NoPE.

Memory discipline (the difference between lowering at 32k and not):

* **Grouped einsums** — queries are shaped (B, S, KV, G, Dh) so the KV tensor
  is never repeated across the G = H/KV query heads per KV head (a 16x blowup
  for Qwen3-MoE's 64q/4kv at decode).
* **Blockwise (flash-style) online-softmax** over KV chunks for S > 2048:
  running (m, l, acc) carried through a ``lax.scan``; peak live score tensor
  is (B, KV, G, S, block) instead of (B, H, S, S) — prefill_32k drops from
  ~1.1 TB of logits to ~68 GB transient, and remat frees it per layer.
* Decode attends one token against a KV cache whose sequence axis may be
  sharded over mesh axes (sequence-parallel decode: XLA inserts the softmax
  all-reduce — flash-decoding's split-KV in SPMD form).

Layer patterns:
  'global'   full causal
  'local'    sliding window `window` (Gemma-2 alternates local/global)
  'chunked'  attention confined to aligned `window` chunks (Llama-4 iRoPE
             local layers; its global layers are 'global' with NoPE)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PATTERNS = ("global", "local", "chunked")
FLASH_THRESHOLD = 2048    # dense path below, blockwise at/above
KV_BLOCK = 1024


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    softcap: float | None = None      # attention-logit soft cap (gemma2: 50)
    rope_theta: float = 10000.0
    window: int = 0                   # for local/chunked patterns


def init_attn(key, d_model: int, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "wq": L.init_dense(ks[0], d_model, cfg.n_heads * cfg.d_head, dtype),
        "wk": L.init_dense(ks[1], d_model, cfg.n_kv_heads * cfg.d_head, dtype),
        "wv": L.init_dense(ks[2], d_model, cfg.n_kv_heads * cfg.d_head, dtype),
        "wo": L.init_dense(ks[3], cfg.n_heads * cfg.d_head, d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.d_head,), dtype)
        p["k_norm"] = jnp.zeros((cfg.d_head,), dtype)
    return p


def _mask(pattern_id: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
          window: int) -> jnp.ndarray:
    """Boolean (q, k) mask for pattern_id in {0: global, 1: local, 2: chunked}."""
    w = max(window, 1)
    causal = k_pos[None, :] <= q_pos[:, None]
    local = causal & (q_pos[:, None] - k_pos[None, :] < w)
    chunked = causal & (q_pos[:, None] // w == k_pos[None, :] // w)
    return jnp.where(pattern_id == 0, causal,
                     jnp.where(pattern_id == 1, local, chunked))


def _grouped_scores(q5, k, scale: float, softcap: float | None):
    """q5 (B,S,KV,G,Dh) x k (B,T,KV,Dh) -> fp32 (B,KV,G,S,T), softcapped."""
    s = jnp.einsum("bskgd,btkd->bkgst", q5, k) * scale
    return L.softcap(s.astype(jnp.float32), softcap)


def _dense_attend(q5, k, v, mask, softcap, scale):
    scores = _grouped_scores(q5, k, scale, softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q5.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def _flash_attend(q5, k, v, pattern_id, window, softcap, scale, block: int):
    """Online-softmax blockwise attention; causal-pattern masks per block."""
    B, S, KV, G, Dh = q5.shape
    T = k.shape[1]
    n_blocks = -(-T // block)
    pad = n_blocks * block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blocks, block, KV, Dh)
    vb = v.reshape(B, n_blocks, block, KV, Dh)
    q_pos = jnp.arange(S, dtype=jnp.int32)

    def body(carry, blk):
        m, l, acc = carry
        k_j, v_j, j = blk
        k_pos = j * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.einsum("bskgd,btkd->bkgst", q5, k_j) * scale        # fp32 below
        s = L.softcap(s.astype(jnp.float32), softcap)
        msk = (_mask(pattern_id, q_pos, k_pos, window)
               & (k_pos < T)[None, :])                              # (S, block)
        s = jnp.where(msk[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bkgst,btkd->bkgsd", p.astype(q5.dtype), v_j)
                   .astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, Dh), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb_t, vb_t, jnp.arange(n_blocks, dtype=jnp.int32)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1).astype(q5.dtype)                 # (B,S,KV,G,Dh)


def attend(params: dict, x: jnp.ndarray, cfg: AttnConfig,
           pattern_id: jnp.ndarray, *, rules: L.MeshRules,
           use_rope: bool = True,
           kv_cache: tuple[jnp.ndarray, jnp.ndarray] | None = None,
           cache_len: jnp.ndarray | None = None) -> tuple[jnp.ndarray, tuple]:
    """x: (B, S, D).  Training/prefill when kv_cache is None; decode (S == 1,
    new token written at slot ``cache_len % S_kv``) otherwise.

    Returns (output (B, S, D), kv pair (B, S_kv, KV, Dh))."""
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // KV
    scale = 1.0 / (Dh ** 0.5)

    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, KV, Dh)
    v = (x @ params["wv"]).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"])
        k = L.rms_norm(k, params["k_norm"])

    if kv_cache is None:
        pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
        if use_rope:                            # static per layer-group position
            q = L.rope(q, pos, cfg.rope_theta)
            k = L.rope(k, pos, cfg.rope_theta)
        q = L.constrain(q, rules, "batch", "seq", "heads", None)
        k = L.constrain(k, rules, "batch", "seq", "kv_heads", None)
        q5 = q.reshape(B, S, KV, G, Dh)
        if S >= FLASH_THRESHOLD:
            o5 = _flash_attend(q5, k, v, pattern_id, cfg.window, cfg.softcap,
                               scale, KV_BLOCK)
        else:
            mask = _mask(pattern_id, jnp.arange(S), jnp.arange(S), cfg.window)
            o5 = _dense_attend(q5, k, v, mask[None, None, None], cfg.softcap, scale)
        out = o5.reshape(B, S, H * Dh) @ params["wo"]
        return out, (k, v)

    # ---- decode: one token vs cache ----------------------------------------
    ck, cv = kv_cache                           # (B, S_kv, KV, Dh)
    S_kv = ck.shape[1]
    if use_rope:
        pos = jnp.broadcast_to(cache_len, (B, 1))
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
    zero = jnp.zeros((), jnp.int32)
    keys = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                        (zero, cache_len % S_kv, zero, zero))
    values = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (zero, cache_len % S_kv, zero, zero))
    keys = L.constrain(keys, rules, "batch", "kv_seq", "kv_heads", None)
    values = L.constrain(values, rules, "batch", "kv_seq", "kv_heads", None)
    kq_pos = jnp.arange(S_kv, dtype=jnp.int32)
    ring_full = cache_len >= S_kv               # window-sized ring has wrapped
    valid = (kq_pos[None, :] <= cache_len) | ring_full
    if S_kv <= max(cfg.window, 1):
        # ring buffer sized to the window: every live slot is in-window
        # (keys were RoPE'd at absolute positions when written)
        mask = valid
    else:
        mask = _mask(pattern_id, jnp.reshape(cache_len, (1,)), kq_pos,
                     cfg.window) & valid
    q5 = q.reshape(B, 1, KV, G, Dh)
    o5 = _dense_attend(q5, keys, values, mask[None, None, None], cfg.softcap, scale)
    out = o5.reshape(B, S, H * Dh) @ params["wo"]
    return out, (keys, values)
