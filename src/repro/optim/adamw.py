"""AdamW with FSDP-sharded states, global-norm clipping, cosine schedule, and
optional error-feedback int8 gradient compression for the cross-pod axis.

Self-contained (no optax dependency in this container).  Optimizer state
mirrors parameter sharding exactly — m/v PartitionSpecs are the parameter
specs, so pjit never replicates the 2x fp32 state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_specs(param_specs) -> AdamWState:
    """Optimizer-state PartitionSpecs = parameter specs (FSDP)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, state: AdamWState, grads, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, m, v, g):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(grads)
    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g):
        a, b, c = upd(p, m, v, g)
        new_p.append(a); new_m.append(b); new_v.append(c)
    return (jax.tree.unflatten(treedef, new_p),
            AdamWState(step=step,
                       m=jax.tree.unflatten(treedef, new_m),
                       v=jax.tree.unflatten(treedef, new_v)),
            {"grad_norm": gnorm, "lr": lr})


# ---------------------------------------------------------------------------
# error-feedback int8 gradient compression (cross-pod / DCN axis)
# ---------------------------------------------------------------------------

class EFState(NamedTuple):
    residual: Any   # fp32 error accumulator, same tree as grads


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_decompress(g: jnp.ndarray, r: jnp.ndarray):
    """Simulate int8 quantize -> (all-reduce) -> dequantize with error feedback.

    Returns (dequantized gradient, new residual).  On real multi-pod meshes the
    quantized payload is what crosses the DCN; the residual keeps the scheme
    unbiased over time (EF-SGD).  8x smaller cross-pod all-reduce payload.
    """
    x = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, x - deq


def ef_compress_tree(grads, ef: EFState):
    pairs = jax.tree.map(compress_decompress, grads, ef.residual)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(residual=res)
