"""Fault-tolerant training driver: checkpoint/restart, straggler watchdog,
simulated failure injection (CPU container stands in for a real pod).

Synchronous-SPMD recovery model (DESIGN.md §4): any node failure kills the
step; the runtime restarts the job from the newest committed checkpoint and
the stateless data pipeline (counter -> batch) resumes at exactly the next
step.  This driver implements that loop in-process so the whole mechanism is
testable here: `run_with_restarts` injects failures and proves bitwise
loss-curve continuity across restarts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.checkpoint import ckpt as ckpt_lib


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time monitor: flags steps slower than `threshold`x the mean.

    On a real pod the flag feeds the scheduler (preempt/replace the slow
    host); here it is recorded for the metrics log and asserted on in tests.
    """
    alpha: float = 0.1
    threshold: float = 3.0
    ewma: float | None = None
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged.append((step, dt))
        return slow


class InjectedFailure(RuntimeError):
    pass


def train_loop(state: dict, n_steps: int, step_fn: Callable,
               batch_fn: Callable, ckpt_dir: str, *, start_step: int = 0,
               ckpt_every: int = 10, fail_at: int | None = None,
               watchdog: StragglerWatchdog | None = None,
               metrics_log: list | None = None) -> dict:
    """Run steps [start_step, n_steps); checkpoint every `ckpt_every`.

    `state` = {"params": ..., "opt": ...}.  Raises InjectedFailure at step
    `fail_at` AFTER mutating state (simulating a mid-interval crash, the
    worst case: work since the last checkpoint is lost).
    """
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    watchdog = watchdog or StragglerWatchdog()
    for step in range(start_step, n_steps):
        t0 = time.time()
        batch = batch_fn(step)
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        state = {"params": params, "opt": opt}
        watchdog.observe(step, time.time() - t0)
        if metrics_log is not None:
            metrics_log.append((step, {k: float(v) for k, v in metrics.items()}))
        if fail_at is not None and step == fail_at:
            raise InjectedFailure(f"injected node failure at step {step}")
        if (step + 1) % ckpt_every == 0:
            saver.save_async(step + 1, state)
    saver.wait()
    ckpt_lib.save(ckpt_dir, n_steps, state)    # final commit
    return state


def run_with_restarts(init_state: dict, n_steps: int, step_fn, batch_fn,
                      ckpt_dir: str, *, ckpt_every: int = 10,
                      failures: tuple[int, ...] = (),
                      metrics_log: list | None = None) -> dict:
    """Full job lifecycle: on failure, restore the newest checkpoint and
    resume — the restart path a cluster runtime would drive."""
    state = init_state
    start = 0
    pending = list(failures)
    while True:
        fail_at = pending[0] if pending else None
        try:
            state = train_loop(state, n_steps, step_fn, batch_fn, ckpt_dir,
                               start_step=start, ckpt_every=ckpt_every,
                               fail_at=fail_at, metrics_log=metrics_log)
            return state
        except InjectedFailure:
            pending.pop(0)
            try:
                state, restored_step = ckpt_lib.restore(ckpt_dir, state)
            except FileNotFoundError:
                state, restored_step = init_state, 0
            start = restored_step
