"""fm: Factorization Machine [Rendle ICDM'10] — 39 sparse features, embed 10,
pairwise interactions via the O(nk) sum-square trick.  Criteo-style 1M-bucket
hashing per feature."""
from repro.configs.recsys_common import RecsysArch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="fm", interaction="fm", n_sparse=39, embed_dim=10,
                    table_rows=(1_000_000,) * 39)
SMOKE = RecsysConfig(name="fm-smoke", interaction="fm", n_sparse=6,
                     embed_dim=10, table_rows=(1000,) * 6)
ARCH = RecsysArch("fm", FULL, SMOKE)
