"""Shared ArchDef for the four recsys towers.

Shapes (assigned set):
  train_batch     B=65,536                     -> train_step
  serve_p99       B=512                        -> online inference
  serve_bulk      B=262,144                    -> offline scoring
  retrieval_cand  B=1, n_candidates=1,000,000  -> top-k candidate scoring
                  (batched-dot over the candidate axis, never a loop —
                   DESIGN.md §5 ties this to the paper's rank-candidates
                   primitive / kernels/topk_score)
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchDef, Cell, sds, F32, I32
from repro.models import recsys

N_CANDIDATES = 1_000_000

SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1),
}


class RecsysArch(ArchDef):
    family = "recsys"

    def __init__(self, name: str, full: recsys.RecsysConfig,
                 smoke: recsys.RecsysConfig):
        self.name = name
        self._full, self._smoke = full, smoke

    def config(self, smoke: bool = False):
        return self._smoke if smoke else self._full

    def cells(self) -> list[Cell]:
        return [Cell(self.name, s, m["kind"]) for s, m in SHAPES.items()]

    def init_params(self, key, cfg):
        return recsys.init_params(key, cfg)

    def param_specs(self, cfg, rules):
        return recsys.param_specs(cfg, rules)

    def _batch(self, cfg, B: int, train: bool) -> dict:
        if cfg.interaction == "self-attn-seq":
            b = {"seq": sds((B, cfg.seq_len), I32)}
            if train:
                b["pos"] = sds((B, cfg.seq_len), I32)
                b["neg"] = sds((B, cfg.seq_len), I32)
            return b
        b = {"sparse": sds((B, cfg.n_sparse), I32)}
        if cfg.n_dense:
            b["dense"] = sds((B, cfg.n_dense), F32)
        if train:
            b["label"] = sds((B,), I32)
        return b

    def abstract_inputs(self, cfg, shape: str) -> dict:
        m = SHAPES[shape]
        if m["kind"] == "retrieval":
            b = self._batch(cfg, 1, train=False)
            b["candidates"] = sds((N_CANDIDATES,), I32)
            return {"batch": b}
        return {"batch": self._batch(cfg, m["batch"], m["kind"] == "train")}

    def input_specs(self, cfg, shape: str, rules) -> dict:
        m = SHAPES[shape]
        row = rules.spec("batch")
        mat = rules.spec("batch", None)
        if m["kind"] == "retrieval":
            specs = {k: P() for k in self._batch(cfg, 1, train=False)}
            specs["candidates"] = rules.spec("batch")  # candidate axis sharded
            return {"batch": specs}
        b = self._batch(cfg, m["batch"], m["kind"] == "train")
        specs = {}
        for k, v in b.items():
            specs[k] = mat if len(v.shape) == 2 else row
        return {"batch": specs}

    def make_step(self, cfg, kind: str, rules):
        if kind == "train":
            return self.train_wrapper(recsys.loss_fn, cfg, rules)
        if kind == "serve":
            def serve_step(params, batch):
                return recsys.serve(params, batch, cfg, rules)
            return serve_step
        if kind == "retrieval":
            def retrieval_step(params, batch):
                return recsys.retrieval_scores(params, batch, cfg, rules, k=100)
            return retrieval_step
        raise ValueError(kind)
