"""qwen3-1.7b: 28L d2048 16H (GQA kv=8, head 128) d_ff 6144, vocab 151936,
qk_norm.  [hf:Qwen/Qwen3 family]"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, smoke_lm
from repro.models import transformer as T

FULL = T.LMConfig(
    name="qwen3-1.7b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1e6,
    dtype=jnp.bfloat16)

# sequence-parallel TP (see granite_3_8b.py + EXPERIMENTS.md §Perf 2)
ARCH = LMArch("qwen3-1.7b", FULL, smoke_lm("qwen3-1.7b", FULL), long_ok=False,
              extra_rules=(("seq", "model"),))
