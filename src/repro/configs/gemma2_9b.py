"""NON-WTBC FIXTURE (seed-era assigned architecture, not the paper system).

Kept solely as a dry-run/roofline harness fixture (``launch/dryrun.py`` mesh
sweeps, ``analysis/roofline.py`` cell tables); nothing in the WTBC retrieval
stack (engine / kernels / serve) imports it.  Do not grow — retrieval work
belongs in ``wtbc_paper.py``.

gemma2-9b: 42L d3584 16H (GQA kv=8, head 256) d_ff 14336, vocab 256000,
alternating local(4096)/global attention, attn softcap 50, final softcap 30,
post-block norms.  [arXiv:2408.00118]"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, smoke_lm
from repro.models import transformer as T

FULL = T.LMConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab=256000,
    pattern=("local", "global"), use_rope_pattern=(True, True),
    window=4096, attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    dtype=jnp.bfloat16)

# sequence-parallel TP (see granite_3_8b.py + EXPERIMENTS.md §Perf 2)
ARCH = LMArch("gemma2-9b", FULL, smoke_lm("gemma2-9b", FULL), long_ok=True,
              extra_rules=(("seq", "model"),))
