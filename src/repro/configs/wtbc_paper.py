"""wtbc: the paper's own system as a dry-run architecture.

Production deployment posture (DESIGN.md §4): a 2-billion-token collection
(~10 GB of text — 10x the paper's corpus) document-sharded over every chip of
the mesh; each shard holds a 4M-token WTBC (+ DRB bitmaps) built with the
*global* (s,c)-DC model; a batch of 64 queries is replicated, solved locally,
and merged with one all-gather of (B, k) scores per shard.

The dry-run lowers the full `distributed_topk` (shard_map + per-shard
Algorithm-1 while_loop + all_gather merge) for the four query methods.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchDef, Cell, sds, F32, I32
from repro.core import distributed as D
from repro.core import scoring
from repro.core.bitvec import BitVec, WORDS_PER_BLOCK
from repro.core.bytemap import ByteMap
from repro.core.drb import DRBAux
from repro.core.wtbc import MAX_LEVELS, WTBCIndex

U8 = jnp.uint8
U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class WTBCDeployConfig:
    name: str = "wtbc"
    tokens_per_shard: int = 4_194_304      # 128 blocks of 32768
    docs_per_shard: int = 6750
    vocab: int = 718_691                   # the paper's ALL-corpus vocabulary
    s: int = 188
    c: int = 68
    block: int = 32768
    query_batch: int = 64
    words_per_query: int = 4
    k: int = 10
    # level-size ratios observed on Zipf corpora with (188,68) codes
    level1_frac: float = 0.5
    level2_frac: float = 0.125


SHAPES = {
    "queries_dr_and": "dr-and",
    "queries_dr_or": "dr-or",
    "queries_drb_and": "drb-and",
    "queries_drb_or": "drb-or",
}


def _abstract_bytemap(n: int, block: int, n_shards: int) -> ByteMap:
    n_pad = max(1, -(-n // block)) * block
    return ByteMap(
        data=sds((n_shards, n_pad), U8),
        counts=sds((n_shards, n_pad // block + 1, 256), jnp.int32),
        length=sds((n_shards,), jnp.int32),
        block=block)


def abstract_sharded(cfg: WTBCDeployConfig, n_shards: int) -> D.ShardedWTBC:
    n, V, D_ = cfg.tokens_per_shard, cfg.vocab, cfg.docs_per_shard
    lvl_sizes = [n, int(n * cfg.level1_frac), int(n * cfg.level2_frac)]
    levels = tuple(_abstract_bytemap(s, cfg.block, n_shards) for s in lvl_sizes)
    offsets = (sds((n_shards, 2), jnp.int32),
               sds((n_shards, cfg.c + 1), jnp.int32),
               sds((n_shards, cfg.c ** 2 + 1), jnp.int32))
    i32v = lambda *shape: sds((n_shards,) + shape, jnp.int32)
    idx = WTBCIndex(
        levels=levels, offsets=offsets,
        cw=sds((n_shards, V, MAX_LEVELS), U8), cw_len=i32v(V),
        node_off=i32v(V, MAX_LEVELS), base_rank=i32v(V, MAX_LEVELS),
        sep_pos=i32v(D_), df=i32v(V), occ=i32v(V), doc_len=i32v(D_),
        n=sds((n_shards,), jnp.int32), n_docs=sds((n_shards,), jnp.int32),
        s=cfg.s, c=cfg.c)
    n_bits = n
    n_words = -(-n_bits // 32)
    n_words = -(-n_words // WORDS_PER_BLOCK) * WORDS_PER_BLOCK
    aux = DRBAux(
        bv=BitVec(words=sds((n_shards, n_words), U32),
                  counts=sds((n_shards, n_words // WORDS_PER_BLOCK + 1), jnp.int32),
                  n_bits=sds((n_shards,), jnp.int32)),
        bit_off=i32v(V + 1), has_bm=sds((n_shards, V), jnp.bool_), eps=1e-6)
    return D.ShardedWTBC(idx=idx, aux=aux, doc_base=i32v(),
                         global_df=sds((V,), jnp.int32),   # replicated
                         global_idf=sds((V,), F32),        # replicated
                         global_avg_dl=sds((), F32),       # replicated
                         n_shards=n_shards)


class WTBCPaperArch(ArchDef):
    """family='retrieval' — handled specially by dryrun (needs the mesh)."""
    family = "retrieval"
    name = "wtbc"

    def config(self, smoke: bool = False) -> WTBCDeployConfig:
        if smoke:
            return WTBCDeployConfig(name="wtbc-smoke", tokens_per_shard=8192,
                                    docs_per_shard=64, vocab=500, s=254, c=2,
                                    block=512, query_batch=2, k=5)
        return WTBCDeployConfig()

    def cells(self) -> list[Cell]:
        return [Cell("wtbc", s, "serve") for s in SHAPES]

    def init_params(self, key, cfg):
        raise NotImplementedError("the WTBC index is built, not initialized")

    def param_specs(self, cfg, rules):
        raise NotImplementedError

    def abstract_inputs(self, cfg, shape: str) -> dict:
        B, Q = cfg.query_batch, cfg.words_per_query
        return {"words": sds((B, Q), I32), "wmask": sds((B, Q), jnp.bool_)}

    def input_specs(self, cfg, shape: str, rules) -> dict:
        return {"words": P(), "wmask": P()}

    def make_step(self, cfg, kind: str, rules):
        raise NotImplementedError("use make_query_fn(mesh, ...)")

    def make_query_fn(self, cfg: WTBCDeployConfig, shape: str, mesh,
                      shard_axes):
        method = SHAPES[shape]
        heap_cap = 2 * cfg.docs_per_shard + 4

        def query(sharded, words, wmask):
            return D.distributed_topk(
                sharded, words, wmask, k=cfg.k, method=method, mesh=mesh,
                shard_axes=shard_axes, heap_cap=heap_cap,
                max_df_cap=min(cfg.docs_per_shard, 2048))
        return query

    def sharded_specs(self, sharded_abs: D.ShardedWTBC,
                      shard_axes: tuple[str, ...]):
        """jit-level in_shardings: every stacked leaf sharded on axis 0 over
        all shard mesh axes jointly."""
        def leaf(l):
            return P(shard_axes, *([None] * (len(l.shape) - 1)))
        return D.ShardedWTBC(
            idx=jax.tree.map(leaf, sharded_abs.idx),
            aux=jax.tree.map(leaf, sharded_abs.aux),
            doc_base=P(shard_axes),
            global_df=P(),
            global_idf=P(),
            global_avg_dl=P(),
            n_shards=sharded_abs.n_shards)


ARCH = WTBCPaperArch()
