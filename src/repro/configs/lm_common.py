"""Shared ArchDef for the five LM-family transformers.

Shapes (the assigned set — seq_len x global_batch):
  train_4k     S=4096   B=256   -> train_step
  prefill_32k  S=32768  B=32    -> prefill
  decode_32k   S=32768  B=128   -> serve_step (decode, KV cache of S)
  long_500k    S=524288 B=1     -> serve_step; needs sub-quadratic attention —
                                   runs only for archs with windowed/chunked
                                   layers (gemma2, llama4); skipped for pure
                                   full-attention archs (DESIGN.md §5)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchDef, Cell, sds, F32, I32
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

LONG_RULES = (("batch", None), ("kv_seq", ("pod", "data", "model")))


class LMArch(ArchDef):
    family = "lm"

    def __init__(self, name: str, cfg_full: T.LMConfig, cfg_smoke: T.LMConfig,
                 long_ok: bool, extra_rules: tuple = ()):
        self.name = name
        self._full = cfg_full
        self._smoke = cfg_smoke
        self._long_ok = long_ok
        self._extra_rules = tuple(extra_rules)

    def config(self, smoke: bool = False) -> T.LMConfig:
        return self._smoke if smoke else self._full

    def cells(self) -> list[Cell]:
        out = []
        for shape, meta in LM_SHAPES.items():
            skip = None
            rules = self._extra_rules
            if shape == "long_500k":
                rules = rules + LONG_RULES
                if not self._long_ok:
                    skip = ("pure full-attention arch: 500k decode has no "
                            "sub-quadratic path (DESIGN.md §5)")
            out.append(Cell(self.name, shape, meta["kind"], skip=skip,
                            rules_overrides=rules))
        return out

    # ---- params --------------------------------------------------------------

    def init_params(self, key, cfg):
        return T.init_params(key, cfg)

    def param_specs(self, cfg, rules):
        return T.param_specs(cfg, rules)

    # ---- inputs ---------------------------------------------------------------

    def abstract_inputs(self, cfg, shape: str) -> dict:
        m = LM_SHAPES[shape]
        B, S = m["batch"], m["seq"]
        if m["kind"] == "train":
            return {"batch": {"tokens": sds((B, S), I32),
                              "labels": sds((B, S), I32)}}
        if m["kind"] == "prefill":
            return {"tokens": sds((B, S), I32)}
        return {"caches": T.cache_shapes(cfg, B, S),
                "tokens": sds((B,), I32),
                "cache_len": sds((), I32)}

    def input_specs(self, cfg, shape: str, rules) -> dict:
        m = LM_SHAPES[shape]
        if m["kind"] == "train":
            tok = rules.spec("batch", "seq")
            return {"batch": {"tokens": tok, "labels": tok}}
        if m["kind"] == "prefill":
            return {"tokens": rules.spec("batch", "seq")}
        cache = P(None, *rules.spec("batch", "kv_seq", "kv_heads", None))
        return {"caches": [ (cache, cache) for _ in cfg.pattern ],
                "tokens": rules.spec("batch"),
                "cache_len": P()}

    # ---- steps ----------------------------------------------------------------

    def make_step(self, cfg, kind: str, rules):
        if kind == "train":
            return self.train_wrapper(T.loss_fn, cfg, rules)
        if kind == "prefill":
            def prefill_step(params, tokens):
                logits, caches = T.prefill(params, tokens, cfg, rules)
                return logits, caches
            return prefill_step
        if kind == "decode":
            def serve_step(params, caches, tokens, cache_len):
                return T.decode_step(params, caches, tokens, cache_len, cfg, rules)
            return serve_step
        raise ValueError(kind)

    def flops_note(self, cfg) -> dict:
        return {"params": cfg.param_count(),
                "active_params": cfg.active_param_count()}


def smoke_lm(name: str, full: T.LMConfig) -> T.LMConfig:
    """Reduced same-family config: keeps pattern/features, shrinks dims."""
    moe = None
    if full.moe is not None:
        moe = dataclasses_replace_moe(full.moe)
    import dataclasses
    return dataclasses.replace(
        full, name=name + "-smoke",
        n_layers=2 * len(full.pattern), d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        window=min(full.window, 8) if full.window else 0,
        moe=moe, dtype=jnp.float32, remat=False)


def dataclasses_replace_moe(m):
    import dataclasses
    return dataclasses.replace(m, n_experts=4, top_k=min(m.top_k, 2), d_ff=64)
