"""--arch registry: the 10 assigned architectures + the paper's own system.

The assigned (non-``wtbc``) entries are seed-era dry-run/roofline fixtures —
they exist so ``launch/dryrun.py`` and the cell-roofline tables have model
shapes to sweep, and are NOT part of the paper's retrieval stack.  Three are
explicitly marked dead in their module docstrings (``gemma2_9b``,
``llama4_scout_17b_a16e``, ``dlrm_mlperf``): kept for the harness, frozen
otherwise.
"""
from __future__ import annotations

from repro.configs import (dlrm_mlperf, egnn, fm, gemma2_9b, granite_3_8b,
                           llama4_scout_17b_a16e, qwen3_1p7b,
                           qwen3_moe_235b_a22b, sasrec, wtbc_paper, xdeepfm)

ARCHS = {a.name: a for a in [
    qwen3_moe_235b_a22b.ARCH,
    llama4_scout_17b_a16e.ARCH,
    gemma2_9b.ARCH,
    qwen3_1p7b.ARCH,
    granite_3_8b.ARCH,
    egnn.ARCH,
    xdeepfm.ARCH,
    fm.ARCH,
    sasrec.ARCH,
    dlrm_mlperf.ARCH,
    wtbc_paper.ARCH,
]}

ASSIGNED = [n for n in ARCHS if n != "wtbc"]


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown --arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells(include_paper: bool = True):
    for name, arch in ARCHS.items():
        if name == "wtbc" and not include_paper:
            continue
        yield from arch.cells()
