"""sasrec [arXiv:1808.09781]: embed 50, 2 self-attention blocks, 1 head,
seq_len 50, next-item training with BCE + 1 sampled negative per position.
Item space 1,000,000 so retrieval_cand scores real candidates."""
from repro.configs.recsys_common import RecsysArch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="sasrec", interaction="self-attn-seq", embed_dim=50,
                    seq_len=50, n_blocks=2, n_heads=1, n_items=1_000_000)
SMOKE = RecsysConfig(name="sasrec-smoke", interaction="self-attn-seq",
                     embed_dim=16, seq_len=10, n_blocks=2, n_heads=1,
                     n_items=500)
ARCH = RecsysArch("sasrec", FULL, SMOKE)
