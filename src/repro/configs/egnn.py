"""egnn: 4 layers, d_hidden 64, E(n)-equivariant [arXiv:2102.09844].

Four shapes, each with its own graph geometry (padded to 4096-multiples so
node/edge axes shard over (pod, data); padding nodes/edges are masked):

  full_graph_sm  Cora-like        N=2,708     E=10,556      d_feat=1,433
  minibatch_lg   Reddit-sampled   1024 seeds, fanout 15-10 (~170k nodes)
  ogb_products   full-batch-large N=2,449,029 E=61,859,140  d_feat=100
  molecule       128 graphs x 30 nodes x 64 edges, graph-level readout

The WTBC technique is inapplicable to geometric message passing
(DESIGN.md §5) — the arch is implemented without it, per the brief.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchDef, Cell, sds, pad_to, F32, I32
from repro.models import gnn

SHAPES = {
    "full_graph_sm": dict(nodes=2708, edges=10556, d_feat=1433, classes=7,
                          readout=False, n_graphs=0),
    "minibatch_lg": dict(nodes=1024 * (1 + 15 + 150), edges=1024 * (15 + 150),
                         d_feat=602, classes=41, readout=False, n_graphs=0),
    "ogb_products": dict(nodes=2_449_029, edges=61_859_140, d_feat=100,
                         classes=47, readout=False, n_graphs=0),
    "molecule": dict(nodes=128 * 30, edges=128 * 64, d_feat=16, classes=2,
                     readout=True, n_graphs=128),
}
PAD = 4096


class EGNNArch(ArchDef):
    family = "gnn"
    name = "egnn"

    def config(self, smoke: bool = False):
        return self.config_for("full_graph_sm", smoke)

    def config_for(self, shape: str, smoke: bool = False) -> gnn.EGNNConfig:
        m = SHAPES[shape]
        if smoke:
            return gnn.EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16,
                                  d_feat=8, n_classes=m["classes"],
                                  graph_readout=m["readout"])
        return gnn.EGNNConfig(name="egnn", n_layers=4, d_hidden=64,
                              d_feat=m["d_feat"], n_classes=m["classes"],
                              graph_readout=m["readout"])

    def cells(self) -> list[Cell]:
        return [Cell("egnn", s, "train") for s in SHAPES]

    def init_params(self, key, cfg):
        return gnn.init_params(key, cfg)

    def param_specs(self, cfg, rules):
        return gnn.param_specs(cfg, rules)

    def abstract_inputs(self, cfg, shape: str) -> dict:
        m = SHAPES[shape]
        N, E = pad_to(m["nodes"], PAD), pad_to(m["edges"], PAD)
        batch = {
            "feats": sds((N, m["d_feat"]), F32),
            "coords": sds((N, 3), F32),
            "edges": sds((E, 2), I32),
        }
        if m["readout"]:
            batch["graph_ids"] = sds((N,), I32)
            batch["labels"] = sds((m["n_graphs"],), I32)
            batch["label_mask"] = sds((m["n_graphs"],), F32)
        else:
            batch["labels"] = sds((N,), I32)
            batch["label_mask"] = sds((N,), F32)
        return {"batch": batch}

    def input_specs(self, cfg, shape: str, rules) -> dict:
        m = SHAPES[shape]
        node = rules.spec("nodes")
        batch = {
            "feats": rules.spec("nodes", None),
            "coords": rules.spec("nodes", None),
            "edges": rules.spec("edges", None),
        }
        if m["readout"]:
            batch["graph_ids"] = node
            batch["labels"] = P()
            batch["label_mask"] = P()
        else:
            batch["labels"] = node
            batch["label_mask"] = node
        return {"batch": batch}

    def make_step(self, cfg, kind: str, rules):
        assert kind == "train"
        return self.train_wrapper(gnn.loss_fn, cfg, rules)


ARCH = EGNNArch()
