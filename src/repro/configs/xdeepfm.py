"""xdeepfm [arXiv:1803.05170]: 39 sparse features, embed 10,
CIN layers 200-200-200 + DNN 400-400 + linear, 1M-bucket hashing."""
from repro.configs.recsys_common import RecsysArch
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="xdeepfm", interaction="cin", n_sparse=39,
                    embed_dim=10, table_rows=(1_000_000,) * 39,
                    cin_layers=(200, 200, 200))
SMOKE = RecsysConfig(name="xdeepfm-smoke", interaction="cin", n_sparse=6,
                     embed_dim=10, table_rows=(1000,) * 6, cin_layers=(16, 16))
ARCH = RecsysArch("xdeepfm", FULL, SMOKE)
