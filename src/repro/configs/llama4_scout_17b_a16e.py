"""NON-WTBC FIXTURE (seed-era assigned architecture, not the paper system).

Kept solely as a dry-run/roofline harness fixture (``launch/dryrun.py`` mesh
sweeps, ``analysis/roofline.py`` cell tables); nothing in the WTBC retrieval
stack (engine / kernels / serve) imports it.  Do not grow — retrieval work
belongs in ``wtbc_paper.py``.

llama4-scout-17b-16e: 48L d5120 40H (GQA kv=8, head 128) d_ff 8192,
vocab 202048, MoE 16 experts top-1 + 1 shared; iRoPE attention — 3 of 4
layers chunked-local (8192), 1 of 4 global with NoPE.  40 heads do not divide
model=16, so attention heads replicate (rules override).  [hf:meta-llama]"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, smoke_lm
from repro.models import transformer as T
from repro.models.moe import MoEConfig

FULL = T.LMConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    pattern=("chunked", "chunked", "chunked", "global"),
    use_rope_pattern=(True, True, True, False),
    window=8192,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1),
    dtype=jnp.bfloat16)

ARCH = LMArch("llama4-scout-17b-a16e", FULL,
              smoke_lm("llama4-scout-17b-a16e", FULL),
              long_ok=True,
              extra_rules=(("heads", None), ("seq", "model")))
