"""granite-3-8b: 40L d4096 32H (GQA kv=8, head 128) d_ff 12800.
True vocab 49155 is padded to 49408 (= 193*256) so the vocab dim divides the
model axis (16); labels never touch the pad rows.  [hf:ibm-granite]"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, smoke_lm
from repro.models import transformer as T

FULL = T.LMConfig(
    name="granite-3-8b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12800, vocab=49408,        # padded from 49155 for TP divisibility
    dtype=jnp.bfloat16)

# Sequence-parallel TP (EXPERIMENTS.md §Perf hillclimb 2): residual-stream
# activations shard S over 'model' between blocks, so the per-layer TP
# all-reduce of (B, S, D) becomes reduce-scatter + all-gather in bf16 at S/16
# per chip (XLA had hoisted that AR into f32 norm fusions: 2x bytes).  GQA KV
# all-gathers are tiny (8 kv heads).  'heads' must then stay unsharded.
ARCH = LMArch("granite-3-8b", FULL, smoke_lm("granite-3-8b", FULL), long_ok=False,
              extra_rules=(("seq", "model"),))
