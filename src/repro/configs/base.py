"""Architecture registry glue: Cell (arch x shape) definitions, abstract
input specs (ShapeDtypeStruct — no allocation), step builders, shardings.

Every assigned architecture provides an ArchDef; ``launch/dryrun.py`` iterates
``arch.cells()`` and lowers ``arch.make_step(kind)`` with
``arch.abstract_inputs(shape)`` under the production mesh.  Smoke tests use
``arch.config(smoke=True)`` + ``arch.concrete_inputs`` at reduced size.
"""
from __future__ import annotations

import abc
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.optim import adamw

F32, I32 = jnp.float32, jnp.int32


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str                         # train | prefill | decode | serve | retrieval
    skip: str | None = None           # reason if this cell is skipped
    rules_overrides: tuple = ()       # ((logical, mesh_axes), ...)

    @property
    def cell_id(self) -> str:
        return f"{self.arch}:{self.shape}"


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def make_rules(mesh_axis_names: tuple[str, ...], cell: Cell | None = None,
               extra: dict | None = None) -> L.MeshRules:
    """Logical rules filtered to the axes that exist on this mesh, with
    per-cell overrides applied."""
    overrides = dict(extra or {})
    if cell is not None:
        overrides.update(dict(cell.rules_overrides))
    merged = dict(L.DEFAULT_RULES)
    merged.update(overrides)

    def keep(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in mesh_axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    return L.MeshRules.make({k: keep(v) for k, v in merged.items()})


class ArchDef(abc.ABC):
    name: str
    family: str

    @abc.abstractmethod
    def config(self, smoke: bool = False): ...

    def config_for(self, shape: str, smoke: bool = False):
        """Per-shape config override hook (EGNN varies d_feat/classes)."""
        return self.config(smoke)

    @abc.abstractmethod
    def cells(self) -> list[Cell]: ...

    @abc.abstractmethod
    def init_params(self, key, cfg): ...

    @abc.abstractmethod
    def param_specs(self, cfg, rules: L.MeshRules): ...

    @abc.abstractmethod
    def abstract_inputs(self, cfg, shape: str) -> dict: ...

    @abc.abstractmethod
    def input_specs(self, cfg, shape: str, rules: L.MeshRules) -> dict: ...

    @abc.abstractmethod
    def make_step(self, cfg, kind: str, rules: L.MeshRules) -> Callable: ...

    # ---- shared helpers ------------------------------------------------------

    def abstract_params(self, cfg):
        return jax.eval_shape(functools.partial(self.init_params, cfg=cfg),
                              jax.random.PRNGKey(0))

    def optimizer_cfg(self) -> adamw.AdamWConfig:
        return adamw.AdamWConfig()

    def train_wrapper(self, loss_fn, cfg, rules):
        ocfg = self.optimizer_cfg()

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, rules)
            params, opt_state, om = adamw.apply_updates(params, opt_state,
                                                        grads, ocfg)
            return params, opt_state, {**metrics, "loss": loss, **om}

        return train_step

    def flops_note(self, cfg) -> dict:
        """Analytic MODEL_FLOPS hints for the roofline (per family)."""
        return {}
