"""NON-WTBC FIXTURE (seed-era assigned architecture, not the paper system).

Kept solely as a dry-run/roofline harness fixture (``launch/dryrun.py`` mesh
sweeps, ``analysis/roofline.py`` cell tables); nothing in the WTBC retrieval
stack (engine / kernels / serve) imports it.  Do not grow — retrieval work
belongs in ``wtbc_paper.py``.

dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM benchmark config (Criteo 1TB):
13 dense + 26 sparse features with the published per-feature cardinalities,
embed 128, bottom MLP 13-512-256-128, dot interaction, top MLP
1024-1024-512-256-1."""
from repro.configs.recsys_common import RecsysArch
from repro.models.recsys import CRITEO_1TB_ROWS, RecsysConfig

FULL = RecsysConfig(name="dlrm-mlperf", interaction="dot", n_sparse=26,
                    n_dense=13, embed_dim=128, table_rows=CRITEO_1TB_ROWS,
                    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1))
SMOKE = RecsysConfig(name="dlrm-smoke", interaction="dot", n_sparse=5,
                     n_dense=4, embed_dim=8, table_rows=(1000,) * 5,
                     bot_mlp=(16, 8), top_mlp=(16, 8, 1))
ARCH = RecsysArch("dlrm-mlperf", FULL, SMOKE)
