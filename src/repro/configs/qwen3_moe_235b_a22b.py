"""qwen3-moe-235b-a22b: 94L d4096 64H (GQA kv=4, head 128) expert-ff 1536,
vocab 151936, MoE 128 experts top-8, qk_norm.  [hf:Qwen/Qwen3-30B-A3B family]"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, smoke_lm
from repro.models import transformer as T
from repro.models.moe import MoEConfig

FULL = T.LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536,                      # (unused: every layer is MoE)
    vocab=151936, qk_norm=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536),
    dtype=jnp.bfloat16)

ARCH = LMArch("qwen3-moe-235b-a22b", FULL, smoke_lm("qwen3-moe-235b-a22b", FULL),
              long_ok=False)
