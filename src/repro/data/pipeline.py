"""Deterministic, stateless data pipelines (counter -> sample).

Every batch is a pure function of (seed, step, family config): restart after a
failure resumes exactly where it left off with O(1) skip-ahead — no iterator
state to checkpoint (DESIGN.md §4 fault tolerance).  On-device generation uses
threefry so the pipeline also runs sharded (each host materializes only its
slice in a real deployment; here we generate globally for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _key(seed: int, step: int, salt: int = 0) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), salt)


# ---------------------------------------------------------------------------
# LM: synthetic token streams (Zipf-ish via squared uniform)
# ---------------------------------------------------------------------------

def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    k1 = _key(seed, step, 1)
    u = jax.random.uniform(k1, (batch, seq + 1))
    toks = (u * u * (vocab - 1)).astype(jnp.int32)   # skewed toward low ids
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# recsys: CTR batches / SASRec sequences
# ---------------------------------------------------------------------------

def recsys_batch(seed: int, step: int, batch: int, cfg) -> dict:
    if cfg.interaction == "self-attn-seq":
        k1, k2, k3 = jax.random.split(_key(seed, step, 2), 3)
        seq = jax.random.randint(k1, (batch, cfg.seq_len), 1, cfg.n_items)
        pos = jnp.concatenate([seq[:, 1:],
                               jax.random.randint(k2, (batch, 1), 1, cfg.n_items)], 1)
        neg = jax.random.randint(k3, (batch, cfg.seq_len), 1, cfg.n_items)
        return {"seq": seq, "pos": pos, "neg": neg}
    ks = jax.random.split(_key(seed, step, 3), 3)
    rows = cfg.rows()
    sparse = jnp.stack(
        [jax.random.randint(jax.random.fold_in(ks[0], f), (batch,), 0, rows[f])
         for f in range(cfg.n_sparse)], axis=1).astype(jnp.int32)
    out = {"sparse": sparse,
           "label": jax.random.bernoulli(ks[1], 0.25, (batch,)).astype(jnp.int32)}
    if cfg.n_dense:
        out["dense"] = jax.random.normal(ks[2], (batch, cfg.n_dense))
    return out


# ---------------------------------------------------------------------------
# GNN: synthetic graphs + deterministic per-step jitter of coordinates
# ---------------------------------------------------------------------------

def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int, pad_nodes: int | None = None,
                 pad_edges: int | None = None) -> dict:
    """Host-side synthetic graph with degree skew, padded + masked."""
    rng = np.random.default_rng(seed)
    pn = pad_nodes or n_nodes
    pe = pad_edges or n_edges
    # preferential-attachment-flavoured endpoints (skewed degrees)
    src = (rng.random(n_edges) ** 2 * n_nodes).astype(np.int64)
    dst = rng.integers(0, n_nodes, n_edges)
    edges = np.zeros((pe, 2), np.int32)
    edges[:n_edges, 0] = src
    edges[:n_edges, 1] = dst
    edges[n_edges:] = pn - 1          # padding edges hit the last (pad) node
    feats = np.zeros((pn, d_feat), np.float32)
    feats[:n_nodes] = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    coords = np.zeros((pn, 3), np.float32)
    coords[:n_nodes] = rng.standard_normal((n_nodes, 3)).astype(np.float32)
    labels = np.zeros((pn,), np.int64)
    labels[:n_nodes] = rng.integers(0, n_classes, n_nodes)
    mask = np.zeros((pn,), np.float32)
    mask[:n_nodes] = 1.0
    return {"feats": jnp.asarray(feats), "coords": jnp.asarray(coords),
            "edges": jnp.asarray(edges), "labels": jnp.asarray(labels.astype(np.int32)),
            "label_mask": jnp.asarray(mask)}


def molecule_batch(seed: int, n_graphs: int, nodes_per: int, edges_per: int,
                   d_feat: int, n_classes: int) -> dict:
    """Block-diagonal batch of small graphs with a graph-level label."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per
    E = n_graphs * edges_per
    src = rng.integers(0, nodes_per, E) + np.repeat(np.arange(n_graphs), edges_per) * nodes_per
    dst = rng.integers(0, nodes_per, E) + np.repeat(np.arange(n_graphs), edges_per) * nodes_per
    return {
        "feats": jnp.asarray(rng.standard_normal((N, d_feat)).astype(np.float32)),
        "coords": jnp.asarray(rng.standard_normal((N, 3)).astype(np.float32)),
        "edges": jnp.asarray(np.stack([src, dst], 1).astype(np.int32)),
        "graph_ids": jnp.asarray(np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, n_classes, n_graphs).astype(np.int32)),
        "label_mask": jnp.ones((n_graphs,), jnp.float32),
    }
