"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanout).

The ``minibatch_lg`` shape requires *real* sampled-subgraph training: 1024
seed nodes, fanout (15, 10).  The full graph lives host-side in CSR; each
step samples a 2-hop neighborhood, relabels it compactly, and pads to the
static shapes the jitted train step was compiled for.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray     # (N+1,)
    indices: np.ndarray    # (E,)
    feats: np.ndarray      # (N, F)
    labels: np.ndarray     # (N,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def random(cls, n_nodes: int, avg_deg: int, d_feat: int, n_classes: int,
               seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        deg = rng.poisson(avg_deg, n_nodes).astype(np.int64)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = rng.integers(0, n_nodes, int(indptr[-1]))
        return cls(indptr=indptr, indices=indices,
                   feats=rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
                   labels=rng.integers(0, n_classes, n_nodes).astype(np.int64))


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanout: tuple[int, ...],
                    pad_nodes: int, pad_edges: int, seed: int = 0) -> dict:
    """Multi-hop fanout sampling -> compact relabeled, padded edge list.

    Returns numpy dict matching the EGNN batch contract: feats/coords/edges/
    labels/label_mask (labels are masked to the seed nodes — the standard
    sampled-training loss).
    """
    rng = np.random.default_rng(seed)
    frontier = np.asarray(seeds, dtype=np.int64)
    all_nodes = [frontier]
    src_list, dst_list = [], []
    for f in fanout:
        next_frontier = []
        for u in frontier:
            nb = g.indices[g.indptr[u]:g.indptr[u + 1]]
            if len(nb) == 0:
                continue
            take = nb if len(nb) <= f else rng.choice(nb, f, replace=False)
            next_frontier.append(take)
            src_list.append(take)
            dst_list.append(np.full(len(take), u, np.int64))
        frontier = (np.unique(np.concatenate(next_frontier))
                    if next_frontier else np.zeros(0, np.int64))
        all_nodes.append(frontier)

    nodes = np.unique(np.concatenate(all_nodes))
    relabel = {int(v): i for i, v in enumerate(nodes)}
    src = np.array([relabel[int(v)] for v in np.concatenate(src_list)], np.int64) \
        if src_list else np.zeros(0, np.int64)
    dst = np.array([relabel[int(v)] for v in np.concatenate(dst_list)], np.int64) \
        if dst_list else np.zeros(0, np.int64)

    n, e = len(nodes), len(src)
    assert n <= pad_nodes and e <= pad_edges, (n, e, pad_nodes, pad_edges)
    feats = np.zeros((pad_nodes, g.feats.shape[1]), np.float32)
    feats[:n] = g.feats[nodes]
    coords = rng.standard_normal((pad_nodes, 3)).astype(np.float32)
    edges = np.full((pad_edges, 2), pad_nodes - 1, np.int32)
    edges[:e, 0] = src
    edges[:e, 1] = dst
    labels = np.zeros(pad_nodes, np.int32)
    labels[:n] = g.labels[nodes]
    mask = np.zeros(pad_nodes, np.float32)
    seed_local = np.array([relabel[int(s)] for s in seeds if int(s) in relabel])
    mask[seed_local] = 1.0            # loss only on seed nodes
    return {"feats": feats, "coords": coords, "edges": edges,
            "labels": labels, "label_mask": mask}
