"""Vocabulary: word <-> id mapping with frequencies (id 0 = '$' separator)."""
from __future__ import annotations

import dataclasses

import numpy as np

SEPARATOR = "$"


@dataclasses.dataclass
class Vocabulary:
    words: list[str]               # id -> word; words[0] == '$'
    ids: dict[str, int]            # word -> id
    freqs: np.ndarray              # (V,) int64 occurrence counts (incl. '$')

    @property
    def size(self) -> int:
        return len(self.words)

    def id_of(self, word: str) -> int:
        return self.ids[word]

    @classmethod
    def from_documents(cls, docs: list[list[str]]) -> "Vocabulary":
        ids: dict[str, int] = {SEPARATOR: 0}
        words = [SEPARATOR]
        counts = [0]
        for doc in docs:
            for w in doc:
                i = ids.get(w)
                if i is None:
                    i = len(words)
                    ids[w] = i
                    words.append(w)
                    counts.append(0)
                counts[i] += 1
            counts[0] += 1  # one '$' per document
        return cls(words=words, ids=ids, freqs=np.asarray(counts, dtype=np.int64))

    def encode_docs(self, docs: list[list[str]]) -> list[np.ndarray]:
        return [np.asarray([self.ids[w] for w in doc], dtype=np.int64) for doc in docs]
