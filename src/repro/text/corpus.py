"""Synthetic natural-language-like corpora (Zipf words, lognormal doc lengths).

The paper evaluates on ~1GB of TREC text (219M words, 718,691-word vocabulary,
345,778 documents).  This container is CPU-only, so benchmarks use scaled-down
corpora drawn from the same statistical family: Zipf(alpha~1.2) unigram
frequencies (natural language word frequencies are near-Zipfian, the regime
(s,c)-DC is designed for) and lognormal document lengths.  Query workloads
mirror the paper's: words sampled uniformly from document-frequency bands
i) 10-100, ii) 101-1k, iii) 1k-10k, iv) 10k-100k (bands rescaled with the
corpus), with 1-6 words per query.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    doc_tokens: list[np.ndarray]   # word ids per document (0 reserved for '$')
    vocab_size: int
    seed: int

    @property
    def n_docs(self) -> int:
        return len(self.doc_tokens)

    @property
    def n_tokens(self) -> int:
        return int(sum(len(d) for d in self.doc_tokens)) + self.n_docs

    def doc_freqs(self) -> np.ndarray:
        """Document frequency per word id."""
        df = np.zeros(self.vocab_size, dtype=np.int64)
        for d in self.doc_tokens:
            df[np.unique(d)] += 1
        df[0] = self.n_docs
        return df


def zipf_probs(vocab_size: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab_size, dtype=np.float64)  # ids 1..V-1 (0 is '$')
    p = ranks ** (-alpha)
    return p / p.sum()


def make_corpus(n_docs: int = 2000, mean_doc_len: int = 400,
                vocab_size: int = 20_000, alpha: float = 1.2,
                seed: int = 0) -> SyntheticCorpus:
    rng = np.random.default_rng(seed)
    lens = np.maximum(2, rng.lognormal(np.log(mean_doc_len), 0.6, n_docs)).astype(np.int64)
    p = zipf_probs(vocab_size, alpha)
    docs = [rng.choice(np.arange(1, vocab_size), size=int(l), p=p) for l in lens]
    return SyntheticCorpus(doc_tokens=docs, vocab_size=vocab_size, seed=seed)


def fdoc_bands(n_docs: int) -> dict[str, tuple[int, int]]:
    """The paper's four document-frequency bands, rescaled to the corpus size.

    Paper bands (345,778 docs): i) 10-100, ii) 101-1,000, iii) 1,001-10,000,
    iv) 10,001-100,000 — i.e. roughly [3e-5..3e-4], [3e-4..3e-3], ... of the
    collection.  We keep the absolute decade structure, clipped to the corpus.
    """
    scale = n_docs / 345_778
    bands = {}
    for name, (lo, hi) in {"i": (10, 100), "ii": (101, 1000),
                           "iii": (1001, 10_000), "iv": (10_001, 100_000)}.items():
        lo_s = max(2, int(lo * scale)) if scale < 1 else lo
        hi_s = max(lo_s + 1, int(hi * scale)) if scale < 1 else hi
        bands[name] = (lo_s, min(hi_s, n_docs))
    return bands


def sample_queries(df: np.ndarray, band: tuple[int, int], n_queries: int,
                   words_per_query: int, seed: int = 0,
                   exclude: int = 0) -> np.ndarray:
    """Sample query word-id sets from a document-frequency band (paper §4.2)."""
    rng = np.random.default_rng(seed)
    lo, hi = band
    pool = np.flatnonzero((df >= lo) & (df <= hi))
    pool = pool[pool != exclude]
    if len(pool) < words_per_query:
        raise ValueError(f"band {band} has only {len(pool)} candidate words")
    return np.stack([rng.choice(pool, size=words_per_query, replace=False)
                     for _ in range(n_queries)])


def sample_ngram_queries(doc_tokens, n_queries: int, q_len: int,
                         seed: int = 0, *, df: np.ndarray | None = None,
                         df_cap: int | None = None, random_prob: float = 0.0,
                         vocab_size: int | None = None) -> np.ndarray:
    """(n_queries, q_len) word-id batches: contiguous n-grams lifted from
    random documents — positional (phrase/near) queries that actually have
    occurrences to rank (independent random words almost never co-occur
    adjacently, which would exercise only the empty-result path).

    df/df_cap:   best-effort rejection (up to 50 draws) of n-grams containing
                 a word with document frequency above ``df_cap`` — the near
                 sweep is O(sum of the query words' occurrences), so Zipf-head
                 stopword grams benchmark the worst case, not the typical one.
    random_prob: probability of replacing an n-gram with uniform random ids
                 in [1, vocab_size) (differential tests want no-match cases).
    """
    rng = np.random.default_rng(seed)
    pool = [d for d in doc_tokens if len(d) >= q_len]
    if not pool:
        raise ValueError(f"no documents with >= {q_len} tokens to lift "
                         f"{q_len}-gram queries from")
    out = np.empty((n_queries, q_len), dtype=np.int64)
    for i in range(n_queries):
        if random_prob and rng.random() < random_prob:
            out[i] = rng.integers(1, vocab_size, size=q_len)
            continue
        for _ in range(50):
            d = pool[int(rng.integers(len(pool)))]
            j = int(rng.integers(0, len(d) - q_len + 1))
            out[i] = d[j:j + q_len]
            if df is None or df_cap is None or int(df[out[i]].max()) <= df_cap:
                break
    return out


def zipf_real_queries(df: np.ndarray, n_queries: int, words_per_query: int,
                      seed: int = 0) -> np.ndarray:
    """'Real-log'-like queries: words drawn with probability ~ df (frequent
    words are queried more), mimicking the head-heavy TREC million-query log."""
    rng = np.random.default_rng(seed)
    w = np.arange(1, len(df))
    p = df[1:].astype(np.float64)
    p = np.where(p > 0, p, 0)
    p = p / p.sum()
    out = np.empty((n_queries, words_per_query), dtype=np.int64)
    for q in range(n_queries):
        out[q] = rng.choice(w, size=words_per_query, replace=False, p=p)
    return out
