"""Wavelet Tree on Bytecodes (WTBC) — level-concatenated, array-native layout.

The paper's WTBC places the i-th bytes of each (s,c)-DC codeword in tree nodes:
the root holds every codeword's first byte in text order; the child ``B_b`` of
the root holds the second byte of every codeword starting with continuer ``b``;
and so on.  Decode walks down with ``rank``; locate walks up with ``select``;
count is a ``rank`` difference at the word's leaf node.

TPU-native adaptation (DESIGN.md §2): instead of pointer-linked nodes we store
**one contiguous byte array per level**; a node is the slice
``[offset, offset+len)`` given by a dense per-level offset table indexed by the
codeword's continuer prefix.  All traversals become integer arithmetic over
static arrays, so every query op below is jit/vmap-compatible.

Per-word acceleration (beyond-paper, free at build time): ``node_off[w, L]``
(absolute offset of the node word ``w`` traverses at level ``L``) and
``base_rank[w, L]`` (rank of ``w``'s level-L byte at that node's start) are
precomputed, halving the rank calls per count/locate.

The document separator '$' is word-rank 0 => its codeword is the single stopper
byte 0 and lives entirely in the root (the paper reserves the first codeword
for '$' for exactly this reason).  Separator positions are additionally kept in
a sorted array ``sep_pos`` — the paper's footnote-2 "faster structures for
those particular cases of select" — making document extents O(1) lookups.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bytemap, scdc
from repro.core.bytemap import ByteMap

MAX_LEVELS = scdc.MAX_CODE_LEN  # 3
SEP_RANK = 0                    # '$' is frequency-rank 0 by construction


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("levels", "offsets", "cw", "cw_len", "node_off", "base_rank",
                 "sep_pos", "df", "occ", "doc_len", "n", "n_docs"),
    meta_fields=("s", "c"))
@dataclasses.dataclass(frozen=True)
class WTBCIndex:
    """The full index: a pytree of device arrays + static (s,c) metadata."""

    levels: tuple[ByteMap, ...]        # MAX_LEVELS ByteMaps (possibly empty)
    offsets: tuple[jnp.ndarray, ...]   # per-level dense node offset tables
    cw: jnp.ndarray                    # (V, MAX_LEVELS) uint8 codeword bytes
    cw_len: jnp.ndarray                # (V,) int32
    node_off: jnp.ndarray              # (V, MAX_LEVELS) int32
    base_rank: jnp.ndarray             # (V, MAX_LEVELS) int32
    sep_pos: jnp.ndarray               # (n_docs,) int32 separator positions in root
    df: jnp.ndarray                    # (V,) int32 document frequency per word-rank
    occ: jnp.ndarray                   # (V,) int32 total occurrences per word-rank
    doc_len: jnp.ndarray               # (n_docs,) int32 tokens per doc (sans '$')
    n: jnp.ndarray                     # () int32 total tokens (incl. separators)
    n_docs: jnp.ndarray                # () int32
    s: int                             # static: stoppers
    c: int                             # static: continuers

    @property
    def vocab_size(self) -> int:
        return self.cw.shape[0]


# ---------------------------------------------------------------------------
# build (host side, numpy)
# ---------------------------------------------------------------------------

def build_index(doc_tokens: list[np.ndarray], vocab_size: int,
                block: int = bytemap.DEFAULT_BLOCK) -> tuple[WTBCIndex, scdc.SCDCModel]:
    """Build the WTBC for a document collection.

    ``doc_tokens``: one int array of word ids per document, word id 0 reserved
    for the separator '$' (never used inside documents).  Returns the index
    (query ids are *frequency ranks*) and the fitted (s,c)-DC model (for
    mapping original word ids <-> ranks).
    """
    n_docs = len(doc_tokens)
    doc_len = np.array([len(d) for d in doc_tokens], dtype=np.int64)
    flat = np.empty(int(doc_len.sum()) + n_docs, dtype=np.int64)
    pos = 0
    for d in doc_tokens:
        flat[pos:pos + len(d)] = d
        flat[pos + len(d)] = 0                      # '$'
        pos += len(d) + 1
    freqs = np.bincount(flat, minlength=vocab_size)
    model = scdc.fit(freqs, reserve_first=0)
    ranks = model.rank_of_word[flat]
    idx = _build_from_ranks(ranks, model, doc_len, block)
    return idx, model


def build_index_with_model(doc_tokens: list[np.ndarray], model: scdc.SCDCModel,
                           block: int = bytemap.DEFAULT_BLOCK) -> WTBCIndex:
    """Build a (shard) index reusing an already-fitted global (s,c)-DC model.

    Document-sharded deployments fit the code **once over the global
    collection** (codewords must agree across shards so queries are
    shard-agnostic), then each shard indexes its own document range.
    """
    n_docs = len(doc_tokens)
    doc_len = np.array([len(d) for d in doc_tokens], dtype=np.int64)
    flat = np.empty(int(doc_len.sum()) + n_docs, dtype=np.int64)
    pos = 0
    for d in doc_tokens:
        flat[pos:pos + len(d)] = d
        flat[pos + len(d)] = 0
        pos += len(d) + 1
    ranks = model.rank_of_word[flat]
    return _build_from_ranks(ranks, model, doc_len, block)


def _build_from_ranks(ranks: np.ndarray, model: scdc.SCDCModel,
                      doc_len: np.ndarray, block: int) -> WTBCIndex:
    s, c = model.s, model.c
    V = model.vocab_size
    codes, lens = model.codes, model.lens
    tok_codes = codes[ranks]                         # (n, 3) uint8
    tok_lens = lens[ranks]                           # (n,)
    n = len(ranks)

    levels: list[ByteMap] = []
    offset_tables: list[np.ndarray] = []
    keys = np.zeros(n, dtype=np.int64)               # continuer-prefix node key
    for L in range(MAX_LEVELS):
        if L == 0:
            offset_tables.append(np.array([0, n], dtype=np.int64))
            levels.append(bytemap.build(tok_codes[:, 0], block))
            continue
        # key at level L extends the key by the continuer byte at level L-1
        alive_prev = tok_lens > (L - 1)
        keys[alive_prev] = keys[alive_prev] * c + (
            tok_codes[alive_prev, L - 1].astype(np.int64) - s)
        sel = np.flatnonzero(tok_lens > L)
        nspace = c ** L
        if len(sel) == 0:
            offset_tables.append(np.zeros(nspace + 1, dtype=np.int64))
            levels.append(bytemap.build(np.zeros(0, dtype=np.uint8), block))
            continue
        keys_sel = keys[sel]
        order = np.argsort(keys_sel, kind="stable")  # group by node, keep text order
        data = tok_codes[sel[order], L]
        sizes = np.bincount(keys_sel, minlength=nspace)
        offs = np.zeros(nspace + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        offset_tables.append(offs)
        levels.append(bytemap.build(data, block))

    # --- per-word node paths -------------------------------------------------
    node_off = np.zeros((V, MAX_LEVELS), dtype=np.int64)
    prefix = np.zeros(V, dtype=np.int64)
    for L in range(1, MAX_LEVELS):
        has = lens > L
        prefix[has] = prefix[has] * c + (codes[has, L - 1].astype(np.int64) - s)
        node_off[has, L] = offset_tables[L][prefix[has]]

    # base ranks: rank of cw[w, L] at node_off[w, L] within level L
    base_rank = np.zeros((V, MAX_LEVELS), dtype=np.int64)
    for L in range(MAX_LEVELS):
        level_data = np.asarray(levels[L].data)[: int(levels[L].length)]
        order = np.argsort(level_data, kind="stable")
        sorted_vals = level_data[order]
        w = np.flatnonzero(lens > L)
        if len(w) == 0 or len(level_data) == 0:
            continue
        b = codes[w, L]
        base_rank_w = np.empty(len(w), dtype=np.int64)
        # positions of byte value bv, ascending, are order[lo:hi]; rank at an
        # offset is a searchsorted into that slice.  Batch words by byte value.
        for bv in np.unique(b):
            sel = b == bv
            lo = np.searchsorted(sorted_vals, bv, side="left")
            hi = np.searchsorted(sorted_vals, bv, side="right")
            occ_positions = np.sort(order[lo:hi])
            base_rank_w[sel] = np.searchsorted(occ_positions, node_off[w[sel], L])
        base_rank[w, L] = base_rank_w

    root = np.asarray(levels[0].data)[:n]
    sep_pos = np.flatnonzero(root == codes[SEP_RANK, 0]).astype(np.int64)
    assert len(sep_pos) == len(doc_len), "separator count must equal n_docs"

    # document frequencies / occurrences per word rank
    n_docs = len(doc_len)
    doc_ids = np.repeat(np.arange(n_docs, dtype=np.int64), (doc_len + 1).astype(np.int64))
    occ = np.bincount(ranks, minlength=V).astype(np.int64)
    pair = ranks.astype(np.int64) * n_docs + doc_ids
    uniq_words = np.unique(pair) // n_docs
    df = np.bincount(uniq_words, minlength=V).astype(np.int64)

    def as_i32(a):
        assert np.max(a, initial=0) < 2**31
        return jnp.asarray(a.astype(np.int32))

    return WTBCIndex(
        levels=tuple(levels),
        offsets=tuple(as_i32(t) for t in offset_tables),
        cw=jnp.asarray(codes),
        cw_len=as_i32(lens.astype(np.int64)),
        node_off=as_i32(node_off),
        base_rank=as_i32(base_rank),
        sep_pos=as_i32(sep_pos),
        df=as_i32(df),
        occ=as_i32(occ),
        doc_len=as_i32(doc_len),
        n=jnp.int32(n),
        n_docs=jnp.int32(len(doc_len)),
        s=s,
        c=c,
    )


# ---------------------------------------------------------------------------
# document geometry ('$' fast path — paper footnote 2)
# ---------------------------------------------------------------------------

def doc_start(idx: WTBCIndex, d: jnp.ndarray) -> jnp.ndarray:
    """First root position of document d (0-based)."""
    return jnp.where(d == 0, 0, idx.sep_pos[jnp.maximum(d - 1, 0)] + 1)


def doc_end(idx: WTBCIndex, d: jnp.ndarray) -> jnp.ndarray:
    """One past the last content position of doc d (its separator position)."""
    return idx.sep_pos[jnp.clip(d, 0, idx.n_docs - 1)]


def segment_extent(idx: WTBCIndex, d0: jnp.ndarray, d1: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Root range [lo, hi) covering documents [d0, d1)."""
    lo = doc_start(idx, d0)
    hi = jnp.where(d1 >= idx.n_docs, idx.n, doc_start(idx, d1))
    return lo, hi


def doc_of_pos(idx: WTBCIndex, pos: jnp.ndarray) -> jnp.ndarray:
    """Document containing root position pos ( = rank_$(T, pos) )."""
    return jnp.searchsorted(idx.sep_pos, pos, side="left").astype(jnp.int32)


# ---------------------------------------------------------------------------
# count / locate / decode (jit + vmap friendly)
# ---------------------------------------------------------------------------

def count_range(idx: WTBCIndex, w: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Occurrences of word-rank ``w`` in root range [lo, hi).

    Descends the tree mapping the range through each level with two ranks per
    level; the word's node offsets/base ranks are precomputed.  Uniform 3-level
    unrolled control flow for clean vmap batching.
    """
    a = lo.astype(jnp.int32)
    b = hi.astype(jnp.int32)
    result = jnp.int32(0)
    for L in range(MAX_LEVELS):
        byte = idx.cw[w, L]
        off = idx.node_off[w, L]
        base = idx.base_rank[w, L]
        ra = bytemap.rank(idx.levels[L], byte, off + a) - base
        rb = bytemap.rank(idx.levels[L], byte, off + b) - base
        is_leaf = idx.cw_len[w] == (L + 1)
        result = jnp.where(is_leaf, rb - ra, result)
        a, b = ra, rb
    return result


def count_range_batch(idx: WTBCIndex, words: jnp.ndarray, los: jnp.ndarray,
                      his: jnp.ndarray) -> jnp.ndarray:
    """Batched count: occurrences of ``words[i]`` in root range
    ``[los[i], his[i])`` for a flat batch of M triples; (M,) int32.

    This is the frontier-batched search cores' rank entry point (DESIGN.md
    §6): the whole (M × levels × 2) rank workload goes down in one shot —
    a single fused ``wavelet_descent`` Pallas launch on TPU, one vectorized
    rank batch per level elsewhere (see ``kernels.ops.wavelet_count_batch``).
    """
    from repro.kernels import ops
    return ops.wavelet_count_batch(idx.levels, idx.cw, idx.cw_len,
                                   idx.node_off, idx.base_rank,
                                   words, los, his)


def count_doc(idx: WTBCIndex, w: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """tf of word-rank w in document d."""
    lo, hi = segment_extent(idx, d, d + 1)
    return count_range(idx, w, lo, hi)


def locate(idx: WTBCIndex, w: jnp.ndarray, j: jnp.ndarray) -> jnp.ndarray:
    """Root position of the j-th (1-based) occurrence of word-rank w.

    Walks leaf -> root with one select per level (paper §2.2 'locating').
    Out-of-range ``j`` (j < 1 or j > occ[w]) is not checked here: each level's
    ``bytemap.select`` saturates to its stream length, so the walk returns a
    position >= the word's last occurrence — typically ``idx.n`` — but callers
    that cannot guarantee ``1 <= j <= idx.occ[w]`` must validate ``j``
    themselves before trusting the result.
    """
    # start: at the leaf level (len-1) the j-th occurrence of w corresponds to
    # the (base_rank + j)-th occurrence of its stopper byte in that level.
    pos = jnp.int32(0)
    for L in range(MAX_LEVELS - 1, -1, -1):
        byte = idx.cw[w, L]
        off = idx.node_off[w, L]
        base = idx.base_rank[w, L]
        is_leaf = idx.cw_len[w] == (L + 1)
        active = idx.cw_len[w] > L
        # occurrence index within this level's byte stream (global, 1-based)
        occ_idx = jnp.where(is_leaf, base + j, base + pos + 1)
        p = bytemap.select(idx.levels[L], byte, occ_idx) - off
        pos = jnp.where(active, p, pos)
    return pos.astype(jnp.int32)


def decode_at(idx: WTBCIndex, pos: jnp.ndarray) -> jnp.ndarray:
    """Word-rank at root position pos (paper §2.2 'decoding').

    Descends with one access + one rank per level, reconstructing the
    (s,c)-DC rank arithmetically from the byte path.
    """
    s, c = idx.s, idx.c
    p = pos.astype(jnp.int32)
    prefix = jnp.int32(0)          # node key at current level
    x = jnp.int32(0)               # accumulated continuer value
    rank_val = jnp.int32(0)
    done = jnp.zeros((), dtype=bool)
    base_k = 0                     # first rank of k-byte band (python, per level)
    width = s
    for L in range(MAX_LEVELS):
        off = idx.offsets[L][prefix]
        b = bytemap.access(idx.levels[L], off + p).astype(jnp.int32)
        is_stop = b < s
        val = jnp.where(is_stop, x * s + b + base_k, 0)
        rank_val = jnp.where(is_stop & ~done, val, rank_val)
        # descend (harmless when done)
        child_rel = (bytemap.rank(idx.levels[L], b.astype(jnp.uint8), off + p)
                     - bytemap.rank(idx.levels[L], b.astype(jnp.uint8), off))
        p = jnp.where(is_stop, p, child_rel)
        prefix = jnp.where(is_stop, prefix, prefix * c + (b - s))
        x = jnp.where(is_stop, x, x * c + (b - s))
        done = done | is_stop
        base_k += width
        width *= c
    return rank_val


def extract(idx: WTBCIndex, lo: jnp.ndarray, length: int) -> jnp.ndarray:
    """Decode ``length`` consecutive word-ranks starting at root position lo
    (snippet extraction; ``length`` static)."""
    return jax.vmap(lambda o: decode_at(idx, lo + o))(jnp.arange(length, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# whole-collection decode — host-side fast path for the DT benchmark
# ---------------------------------------------------------------------------

def decode_all_np(idx: WTBCIndex, model: scdc.SCDCModel) -> np.ndarray:
    """Reconstruct the full token stream (frequency ranks) from the level
    arrays by inverting the stable grouping — the sequential-decompression
    analogue used for the paper's Table-1 'DT' measurement."""
    s, c = idx.s, idx.c
    root = np.asarray(idx.levels[0].data)[: int(idx.levels[0].length)]
    n = len(root)
    x = np.zeros(n, dtype=np.int64)
    lens = np.ones(n, dtype=np.int64)
    bytes_L = root.astype(np.int64)
    alive = np.arange(n)
    prefix = np.zeros(n, dtype=np.int64)
    for L in range(MAX_LEVELS):
        if L > 0:
            level = np.asarray(idx.levels[L].data)[: int(idx.levels[L].length)]
            offs = np.asarray(idx.offsets[L])
            # tokens alive at this level, grouped by node key in text order:
            order = np.argsort(prefix[alive], kind="stable")
            bytes_for = np.empty(len(alive), dtype=np.int64)
            bytes_for[order] = level[: len(alive)]
            bytes_L = bytes_for
            lens[alive] += 1
        cont = bytes_L >= s
        x[alive] = x[alive] * np.where(cont, c, s) + np.where(cont, bytes_L - s, bytes_L)
        prefix_new = prefix[alive] * c + (bytes_L - s)
        keep = alive[cont]
        prefix_next = np.zeros(n, dtype=np.int64)
        prefix_next[keep] = prefix_new[cont]
        prefix = prefix_next
        alive = keep
        if len(alive) == 0:
            break
    bases = np.zeros(MAX_LEVELS + 1, dtype=np.int64)
    base, width = 0, s
    for k in range(1, MAX_LEVELS + 1):
        bases[k] = base
        base, width = base + width, width * c
    return bases[lens] + x


def space_report(idx: WTBCIndex) -> dict[str, int]:
    """Bytes per component — feeds the Table-1 compression-ratio benchmark."""
    def nbytes(a):
        return int(np.asarray(a).nbytes)
    report = {
        # l.length is a scalar on single-host indexes and a per-shard vector
        # on sharded ones — sum over whatever shape it has
        "level_bytes": sum(int(np.asarray(l.length).sum()) for l in idx.levels),
        "rank_counters": sum(nbytes(l.counts) for l in idx.levels),
        "node_offsets": sum(nbytes(o) for o in idx.offsets),
        "codeword_tables": nbytes(idx.cw) + nbytes(idx.cw_len)
                           + nbytes(idx.node_off) + nbytes(idx.base_rank),
        "sep_positions": nbytes(idx.sep_pos),
        "df_occ_doclen": nbytes(idx.df) + nbytes(idx.occ) + nbytes(idx.doc_len),
    }
    report["total"] = sum(report.values())
    return report
