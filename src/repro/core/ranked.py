"""WTBC-DR: ranked retrieval with *no extra space* (paper §3.1, Algorithm 1).

Best-first search over segments (concatenations of consecutive documents),
driven by a priority queue keyed on segment tf-idf.  The whole collection is
the initial segment; popped multi-document segments are split at the document
boundary nearest their middle; a popped single-document segment is the next
most relevant answer (tf-idf is monotone over concatenation).  Conjunctive
(AND) queries additionally discard any segment in which some query word has
tf = 0.

Faithfulness + two deliberate deviations (DESIGN.md §2):

* Segments are document ranges ``[d0, d1)`` rather than byte ranges; the
  midpoint-'$' search ``select_$(T, rank_$(T, (a+b)/2))`` collapses to integer
  arithmetic on the separator-position array — the paper's own footnote-2
  "faster structure for select_$".
* The paper stores one score per segment and derives the sibling score by
  *float* subtraction.  We store the integer tf vector in the heap payload:
  the sibling's tf is obtained by exact integer subtraction (same saving — one
  ``count_range`` per split, not two) and its score is recomputed from tf, so
  scores carry no accumulated float error and conjunctive emptiness checks
  (tf == 0) are exact.

**Frontier batching** (DESIGN.md §6): each ``while_loop`` iteration pops the
``beam_width`` (= P) best segments at once, computes all P×Q left-child term
frequencies with ONE fused batched descent (``wtbc.count_range_batch``), and
bulk-reinserts the children.  Emission stays exact: a popped singleton is
emitted only if it precedes — in the heap's *total* lex order
``(score desc, d0 asc, d1 desc)``, ties included — everything still pending:
the heap top after the pops and every popped multi-document segment (whose
descendants it strictly bounds); the rest are pushed back.  Because the
order is total, the emission sequence is invariant across beam widths and
insertion schedules, bitwise (tests/test_mega.py pins this).
``beam_width=1`` reproduces the classical one-pop
Algorithm 1 exactly (same pop order, same emission, same heap evolution);
larger P trades a few extra segment expansions for P-wide memory-level
parallelism in the rank workload — the compact-top-k batching lever of
Konow & Navarro's "Faster Compact Top-k Document Retrieval".

**Active-frontier buckets** (this file's padding fix, DESIGN.md §9): a beam
trip at configured width P used to descend P×Q rank rows even when the heap
held a single live segment — at P=64 that made most of the descent traffic
dead padding (BENCH_PR7's 11 ms/call pathology).  Each trip now dispatches
on the *live* frontier width ``min(heap.size, P)`` through a
``lax.switch`` over pow2-bucketed loop bodies (1, 2, 4, …, P), so the
descent batch is sized to the work that exists.  Bucketing is bitwise
inert: ``pop_p`` pops come out as a valid-prefix in the total lex order, a
bucket S always satisfies ``min(size, P) <= S <= P`` (so the popped *set*
per trip is identical at any bucket), and dead lanes never emit or push.
The batched entry point ``topk_dr_batch`` runs one explicitly batched loop
with a *scalar* bucket index (max live width across the batch) — under
``vmap`` a batched switch index would execute every branch and select,
erasing the win, so the switch must stay unbatched.  Pad-waste (dead pop
lanes descended) is surfaced as ``DRResult.padded`` →
``SearchResults.diagnostics``.

The full search is one jitted ``lax.while_loop`` per query row; batched
queries share one loop whose trip count is the max over rows (finished rows
are mask-frozen exactly as ``vmap`` of a ``while_loop`` would).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import heap as H
from repro.core import wtbc
from repro.core.wtbc import WTBCIndex


class DRResult(NamedTuple):
    docs: jnp.ndarray    # (k,) int32, -1 padded, sorted by descending score
    scores: jnp.ndarray  # (k,) float32, -inf padded
    n_found: jnp.ndarray # () int32
    iters: jnp.ndarray   # () int32 — while-loop trips (work metric for §Perf)
    # () int32 — segments actually popped (== iters at beam_width=1); the
    # beam's emitted-doc overhead metric is pops(P) / pops(1)
    pops: jnp.ndarray | None = None
    # () bool — a heap push was dropped at capacity: the ranking may be
    # inexact and the caller must not trust it silently (DESIGN.md §6)
    overflowed: jnp.ndarray | None = None
    # () int32 — dead pop lanes whose descent rows were still computed
    # (pad-waste): pops + padded = beam lanes processed.  The active-frontier
    # buckets keep this near zero; None on cores without beam padding (mega,
    # brute force, sharded merge).
    padded: jnp.ndarray | None = None
    # (k,) bool — anytime certification (DESIGN.md §11): slot i is certified
    # iff its key lex-beats the pending bound at the stopping point, i.e. it
    # provably equals the exact oracle's slot i.  All-True whenever the
    # search ran to completion; certified bits always form a prefix.
    certified: jnp.ndarray | None = None
    # () float32 — score upper bound on every document NOT in ``docs``
    # (the lex-max pending segment score at stop); -inf when the frontier
    # was exhausted, i.e. nothing relevant remains.
    bound: jnp.ndarray | None = None


def count_words_range(idx: WTBCIndex, words: jnp.ndarray,
                      lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """tf of each query word in root range [lo, hi); (Q,) int32.

    One batched descent for the whole word set (kernels-on-TPU: a single
    fused ``wavelet_descent`` launch)."""
    Q = words.shape[0]
    return wtbc.count_range_batch(idx, words, jnp.broadcast_to(lo, (Q,)),
                                  jnp.broadcast_to(hi, (Q,)))


def _frontier_buckets(P: int) -> tuple[int, ...]:
    """Pow2 frontier-width buckets 1, 2, 4, …, capped by (and always
    including) the configured beam width P."""
    ws = []
    w = 1
    while w < P:
        ws.append(w)
        w *= 2
    ws.append(P)
    return tuple(ws)


def _tree_select(mask, new, old):
    """Per-row freeze: where ``mask`` (B,) is False, keep ``old`` — the same
    per-row select ``vmap`` of a ``while_loop`` lowers its body to."""
    def sel(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


def _dr_row_init(idx, words, wmask, idf_w, *, k, conjunctive, heap_cap):
    """Per-row loop state: (heap, out_docs, out_scores, n_out, it, pops,
    padded).  ``words``/``wmask``/``idf_w`` are one query row (Q,)."""
    Q = words.shape[0]
    n_docs = idx.n_docs
    lo0, hi0 = wtbc.segment_extent(idx, jnp.int32(0), n_docs)
    tf0 = count_words_range(idx, words, lo0, hi0) * wmask
    score0 = tf0.astype(jnp.float32) @ idf_w
    if conjunctive:
        en0 = jnp.all((tf0 > 0) | ~wmask, axis=-1) & jnp.any(wmask)
    else:
        en0 = score0 > 0.0
    pay0 = jnp.concatenate([jnp.stack([jnp.int32(0), n_docs]), tf0])
    hp = H.make(heap_cap, 2 + Q)
    hp = H.push(hp, score0, pay0, en0)
    # emission order is already globally sorted; track an explicit write
    # cursor.  Slot k is a trash slot for beam emissions past the k budget.
    out_docs = jnp.full((k + 1,), -1, jnp.int32)
    out_scores = jnp.full((k + 1,), -jnp.inf, jnp.float32)
    return (hp, out_docs, out_scores, jnp.int32(0), jnp.int32(0),
            jnp.int32(0), jnp.int32(0))


def _dr_row_cond(st, *, k, max_pops):
    hp, _, _, n_out, _, pops, _ = st
    ok = (n_out < k) & (hp.size > 0)
    if max_pops is not None:
        ok = ok & (pops < max_pops)
    return ok


def _dr_row_body(st, words, wmask, idf_w, *, idx, S, k, conjunctive):
    """One beam trip of one query row at (bucketed) frontier width ``S``.

    Bitwise-identical to running the trip at any width in [min(size, P), P]:
    pops come out as a valid-prefix in the total lex order, and dead lanes
    (valid False) neither emit nor push — only ``padded`` sees them.
    """
    hp, out_docs, out_scores, n_out, it, pops, padded = st
    Q = words.shape[0]

    def seg_score(tf):
        # (..., Q) int32 -> (...,) float32; matvec == the one-pop jnp.dot
        return tf.astype(jnp.float32) @ idf_w

    def seg_valid(tf, score):
        if conjunctive:
            return jnp.all((tf > 0) | ~wmask, axis=-1) & jnp.any(wmask)
        return score > 0.0

    s_p, pay, valid, hp = H.pop_p(hp, S)          # scores descending
    d0, d1, tf = pay[:, 0], pay[:, 1], pay[:, 2:]
    single = valid & ((d1 - d0) == 1)
    multi = valid & ~single

    # exact-emission bound: everything still pending is lex-bounded by
    # the heap top after the S pops and the popped multis' own keys — a
    # segment's key (score desc, d0 asc, d1 desc) strictly bounds every
    # descendant's (score is monotone over concatenation; on score ties
    # a left child keeps d0 but shrinks d1, a right child grows d0).  A
    # popped singleton that lex-beats the bound is the globally next
    # answer *including tie order*, so the emission sequence is the same
    # for every beam width; the rest go back into the heap.
    cs = jnp.concatenate([s_p, hp.scores[:1]])
    c0 = jnp.concatenate([d0, hp.payload[:1, 0]])
    c1 = jnp.concatenate([d1, hp.payload[:1, 1]])
    cv = jnp.concatenate([multi, (hp.size > 0)[None]])
    j = H.lex_argmax(cs, c0, c1, cv)
    emit = single & (~jnp.any(cv)
                     | H.lex_gt(s_p, d0, d1, cs[j], c0[j], c1[j]))
    slot = n_out + jnp.cumsum(emit.astype(jnp.int32)) - 1
    write = emit & (slot < k)
    at = jnp.where(write, slot, k)
    out_docs = out_docs.at[at].set(jnp.where(write, d0, out_docs[at]))
    out_scores = out_scores.at[at].set(
        jnp.where(write, s_p, out_scores[at]))
    n_out = jnp.minimum(n_out + jnp.sum(emit.astype(jnp.int32)), k)

    # split every popped multi at the doc boundary nearest its middle;
    # all S×Q left-child tfs in ONE batched descent (degenerate math on
    # masked lanes is discarded by the push enables)
    mid = (d0 + d1) // 2
    lo1, hi1 = wtbc.segment_extent(idx, d0, mid)
    tf1 = wtbc.count_range_batch(
        idx, jnp.tile(words, S), jnp.repeat(lo1, Q),
        jnp.repeat(hi1, Q)).reshape(S, Q) * wmask
    tf2 = tf - tf1
    s1, s2 = seg_score(tf1), seg_score(tf2)
    pay1 = jnp.concatenate([jnp.stack([d0, mid], axis=1), tf1], axis=1)
    pay2 = jnp.concatenate([jnp.stack([mid, d1], axis=1), tf2], axis=1)
    # bulk reinsert, parent-major (left, right, unemitted single): at
    # S=1 this is push(left), push(right) — the one-pop order exactly.
    # (At S=1 the popped item IS the heap max, so a popped singleton
    # always clears the threshold and the re-push slot is statically
    # dead — drop it to keep the one-pop bucket at the classical cost.)
    slots = ([s1, s2], [pay1, pay2],
             [multi & seg_valid(tf1, s1), multi & seg_valid(tf2, s2)])
    if S > 1:
        slots[0].append(s_p)
        slots[1].append(pay)
        slots[2].append(single & ~emit)
    W = len(slots[0])
    push_s = jnp.stack(slots[0], axis=1).reshape(W * S)
    push_pay = jnp.stack(slots[1], axis=1).reshape(W * S, 2 + Q)
    push_en = jnp.stack(slots[2], axis=1).reshape(W * S)
    hp = H.push_many(hp, push_s, push_pay, push_en)
    nv = jnp.sum(valid.astype(jnp.int32))
    return (hp, out_docs, out_scores, n_out, it + 1, pops + nv,
            padded + (S - nv))


def _bucket_index(n_live, buckets):
    """Scalar index of the smallest bucket >= n_live (n_live >= 1)."""
    return sum((n_live > w).astype(jnp.int32) for w in buckets[:-1])


def _anytime_finalize(hp: H.Heap, out_docs, out_scores, n_out, *, k: int,
                      harvest: bool):
    """Anytime epilogue of one row (DESIGN.md §11): harvest + certify.

    Runs after the while_loop on the per-row heap state.  Two steps:

    1. **Harvest** (only when an anytime budget was in play): fill the
       remaining output slots best-k-so-far with the lex-greatest pending
       *singleton* segments — real documents with exact scores, just not yet
       proven to beat every hidden document.  When the budget never bound,
       the loop only exits with ``n_out == k`` or an empty heap, so the
       harvest writes nothing and every leaf is bitwise what it was.
    2. **Certify**: the pending bound is the lex-max key over everything
       still in the heap (multis bound all their descendants by key
       monotonicity; singletons bound themselves).  A slot is certified iff
       its own key ``(score, d, d+1)`` lex-beats that bound — emitted slots
       always do (the emission rule already proved them against the whole
       pending set, whose keys only decrease); harvested slots only when no
       hidden document can outrank them.  ``overflowed`` voids the bound (a
       dropped push's descendants are unaccounted for), so it vetoes
       certification.

    Returns ``(out_docs, out_scores, n_out, certified (k,), bound ())``.
    """
    s, d0, d1 = hp.scores, hp.payload[:, 0], hp.payload[:, 1]
    valid = jnp.arange(hp.cap, dtype=jnp.int32) < hp.size
    single = valid & ((d1 - d0) == 1)
    remaining = valid

    if harvest:
        def step(_, st):
            out_docs, out_scores, n_out, sing = st
            j = H.lex_argmax(s, d0, d1, sing)
            write = jnp.any(sing) & (n_out < k)
            at = jnp.where(write, n_out, k)
            out_docs = out_docs.at[at].set(
                jnp.where(write, d0[j], out_docs[at]))
            out_scores = out_scores.at[at].set(
                jnp.where(write, s[j], out_scores[at]))
            sing = sing.at[j].set(sing[j] & ~write)
            return out_docs, out_scores, n_out + write.astype(jnp.int32), sing

        out_docs, out_scores, n_out, left = jax.lax.fori_loop(
            0, k, step, (out_docs, out_scores, n_out, single))
        remaining = (valid & ~single) | left

    has_rem = jnp.any(remaining)
    j = H.lex_argmax(s, d0, d1, remaining)
    bnd_s = jnp.where(has_rem, s[j], H.NEG_INF)
    bnd_d0 = jnp.where(has_rem, d0[j], H.INT32_MAX)
    bnd_d1 = jnp.where(has_rem, d1[j], H.INT32_MIN)
    filled = jnp.arange(out_docs.shape[0], dtype=jnp.int32) < n_out
    certified = filled & ~hp.overflowed & H.lex_gt(
        out_scores, out_docs, out_docs + 1, bnd_s, bnd_d0, bnd_d1)
    return out_docs, out_scores, n_out, certified[:k], bnd_s


@functools.partial(jax.jit,
                   static_argnames=("k", "conjunctive", "heap_cap", "max_pops",
                                    "beam_width"))
def topk_dr(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
            idf: jnp.ndarray, *, k: int, conjunctive: bool,
            heap_cap: int, max_pops: int | None = None,
            beam_width: int = 1) -> DRResult:
    """Algorithm 1, frontier-batched.  ``words`` (Q,) word-ranks, ``wmask``
    (Q,) valid-word mask, ``idf`` (V,) precomputed idf table.  ``heap_cap``
    >= 2*n_docs + 2 makes the search exact (the implicit split tree has
    < 2*n_docs nodes; beam re-pushes never exceed that bound because a
    segment occupies at most one heap slot at a time).

    ``max_pops`` is the any-time budget (straggler mitigation, DESIGN.md §4):
    the search stops once that many segments have been popped and returns the
    documents emitted so far — every emitted document is still exactly
    ranked.  With ``beam_width`` = P > 1 the budget is enforced at iteration
    granularity (overshoot < P).

    ``beam_width`` = P pops *up to* P segments per iteration and batches
    their rank workload into one fused call sized to the live frontier
    (pow2 buckets — see the module docstring); P=1 is the classical exact
    pop order.  Results are bitwise-identical across widths and buckets.
    """
    P = int(beam_width)
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)
    st0 = _dr_row_init(idx, words, wmask, idf_w, k=k,
                       conjunctive=conjunctive, heap_cap=heap_cap)

    def cond(st):
        return _dr_row_cond(st, k=k, max_pops=max_pops)

    buckets = _frontier_buckets(P)

    def mk(S):
        return lambda st: _dr_row_body(st, words, wmask, idf_w, idx=idx,
                                       S=S, k=k, conjunctive=conjunctive)

    bodies = [mk(S) for S in buckets]
    if len(buckets) == 1:
        body = bodies[0]
    else:
        def body(st):
            # scalar bucket index: plain jit executes ONE branch per trip
            n_live = jnp.minimum(st[0].size, P)
            return jax.lax.switch(_bucket_index(n_live, buckets), bodies, st)

    hp, out_docs, out_scores, n_out, iters, pops, padded = \
        jax.lax.while_loop(cond, body, st0)
    out_docs, out_scores, n_out, certified, bound = _anytime_finalize(
        hp, out_docs, out_scores, n_out, k=k, harvest=max_pops is not None)
    return DRResult(out_docs[:k], out_scores[:k], n_out, iters, pops,
                    hp.overflowed, padded, certified, bound)


@functools.partial(jax.jit,
                   static_argnames=("k", "conjunctive", "heap_cap", "max_pops",
                                    "beam_width"))
def topk_dr_batch(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
                  idf: jnp.ndarray, *, k: int, conjunctive: bool,
                  heap_cap: int, max_pops: int | None = None,
                  beam_width: int = 1) -> DRResult:
    """Batched queries: ``words``/``wmask`` are (B, Q).

    One explicitly batched loop instead of ``vmap(topk_dr)``: the loop body
    is the *vmapped* per-row trip (so row math — and therefore every result
    leaf — is bitwise what the vmapped serial core produced), but the
    frontier bucket is chosen by a **scalar** index, the max live width
    across still-live rows.  Under ``vmap`` a per-row ``lax.switch`` index
    is batched, which executes every branch and selects — paying for all
    buckets at once; hoisting the dispatch above the vmapped body keeps the
    one-branch-per-trip property the padding fix exists for.  Rows that
    finish early are mask-frozen per trip, exactly the select that
    ``vmap(while_loop)`` lowers to, so per-row ``iters``/``pops`` stay
    row-exact.

    ``padded`` is the one leaf that reflects the batched SCHEDULE rather
    than the per-row computation: a row whose frontier is narrower than the
    batch's max live width pops padded lanes the serial per-row bucket
    would avoid, so batch ``padded`` >= serial ``padded`` row-wise (every
    other leaf is bitwise equal).
    """
    B, Q = words.shape
    P = int(beam_width)
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)   # (B, Q)
    st0 = jax.vmap(lambda w, m, iw: _dr_row_init(
        idx, w, m, iw, k=k, conjunctive=conjunctive, heap_cap=heap_cap))(
            words, wmask, idf_w)

    def lives(st):
        return jax.vmap(lambda s: _dr_row_cond(s, k=k, max_pops=max_pops))(st)

    def cond(st):
        return jnp.any(lives(st))

    buckets = _frontier_buckets(P)

    def mk(S):
        row = lambda s, w, m, iw: _dr_row_body(s, w, m, iw, idx=idx, S=S,
                                               k=k, conjunctive=conjunctive)

        def body_S(st):
            live = lives(st)
            new = jax.vmap(row)(st, words, wmask, idf_w)
            return _tree_select(live, new, st)
        return body_S

    bodies = [mk(S) for S in buckets]
    if len(buckets) == 1:
        body = bodies[0]
    else:
        def body(st):
            # the bucket index is a SCALAR (max live width over the batch):
            # every row pops its full min(size, P) this trip — identical
            # pop set — while the descent batch shrinks to the widest live
            # frontier instead of the configured P
            live = lives(st)
            n_live = jnp.max(jnp.where(live, jnp.minimum(st[0].size, P), 0))
            return jax.lax.switch(_bucket_index(n_live, buckets), bodies, st)

    hp, out_docs, out_scores, n_out, iters, pops, padded = \
        jax.lax.while_loop(cond, body, st0)
    out_docs, out_scores, n_out, certified, bound = jax.vmap(
        functools.partial(_anytime_finalize, k=k,
                          harvest=max_pops is not None))(
        hp, out_docs, out_scores, n_out)
    return DRResult(out_docs[:, :k], out_scores[:, :k], n_out, iters, pops,
                    hp.overflowed, padded, certified, bound)


# ---------------------------------------------------------------------------
# brute-force oracle (tests + benchmark ground truth)
# ---------------------------------------------------------------------------

def topk_bruteforce(idx: WTBCIndex, words, wmask, idf, *, k: int,
                    conjunctive: bool) -> DRResult:
    """Score every document directly with count_range — O(N*Q) oracle."""
    n_docs = int(idx.n_docs)
    words = jnp.asarray(words)
    wmask = jnp.asarray(wmask)
    idf_w = jnp.where(wmask, idf[words], 0.0)

    def score_doc(d):
        lo, hi = wtbc.segment_extent(idx, d, d + 1)
        tf = count_words_range(idx, words, lo, hi) * wmask
        s = jnp.dot(tf.astype(jnp.float32), idf_w)
        if conjunctive:
            ok = jnp.all((tf > 0) | ~wmask) & jnp.any(wmask)
        else:
            ok = s > 0
        return jnp.where(ok, s, -jnp.inf)

    scores = jax.lax.map(score_doc, jnp.arange(n_docs, dtype=jnp.int32))
    top_s, top_d = jax.lax.top_k(scores, k)
    found = jnp.sum(top_s > -jnp.inf).astype(jnp.int32)
    top_d = jnp.where(top_s > -jnp.inf, top_d, -1)
    return DRResult(top_d.astype(jnp.int32), top_s, found, jnp.int32(n_docs),
                    jnp.int32(n_docs), jnp.zeros((), bool),
                    certified=top_s > -jnp.inf, bound=H.NEG_INF)
