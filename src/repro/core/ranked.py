"""WTBC-DR: ranked retrieval with *no extra space* (paper §3.1, Algorithm 1).

Best-first search over segments (concatenations of consecutive documents),
driven by a priority queue keyed on segment tf-idf.  The whole collection is
the initial segment; popped multi-document segments are split at the document
boundary nearest their middle; a popped single-document segment is the next
most relevant answer (tf-idf is monotone over concatenation).  Conjunctive
(AND) queries additionally discard any segment in which some query word has
tf = 0.

Faithfulness + two deliberate deviations (DESIGN.md §2):

* Segments are document ranges ``[d0, d1)`` rather than byte ranges; the
  midpoint-'$' search ``select_$(T, rank_$(T, (a+b)/2))`` collapses to integer
  arithmetic on the separator-position array — the paper's own footnote-2
  "faster structure for select_$".
* The paper stores one score per segment and derives the sibling score by
  *float* subtraction.  We store the integer tf vector in the heap payload:
  the sibling's tf is obtained by exact integer subtraction (same saving — one
  ``count_range`` per split, not two) and its score is recomputed from tf, so
  scores carry no accumulated float error and conjunctive emptiness checks
  (tf == 0) are exact.

The full search is one jitted ``lax.while_loop``; batched queries via ``vmap``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import heap as H
from repro.core import wtbc
from repro.core.wtbc import WTBCIndex


class DRResult(NamedTuple):
    docs: jnp.ndarray    # (k,) int32, -1 padded, sorted by descending score
    scores: jnp.ndarray  # (k,) float32, -inf padded
    n_found: jnp.ndarray # () int32
    iters: jnp.ndarray   # () int32 — pops performed (work metric for §Perf)


def count_words_range(idx: WTBCIndex, words: jnp.ndarray,
                      lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """tf of each query word in root range [lo, hi); (Q,) int32."""
    return jax.vmap(lambda w: wtbc.count_range(idx, w, lo, hi))(words)


@functools.partial(jax.jit,
                   static_argnames=("k", "conjunctive", "heap_cap", "max_pops"))
def topk_dr(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
            idf: jnp.ndarray, *, k: int, conjunctive: bool,
            heap_cap: int, max_pops: int | None = None) -> DRResult:
    """Algorithm 1.  ``words`` (Q,) word-ranks, ``wmask`` (Q,) valid-word mask,
    ``idf`` (V,) precomputed idf table.  ``heap_cap`` >= 2*n_docs + 2 makes the
    search exact (the implicit split tree has < 2*n_docs nodes).

    ``max_pops`` is the any-time budget (straggler mitigation, DESIGN.md §4):
    the search stops after that many queue pops and returns the documents
    emitted so far — every emitted document is still exactly ranked."""
    Q = words.shape[0]
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)

    def seg_score(tf):
        return jnp.dot(tf.astype(jnp.float32), idf_w)

    def seg_valid(tf, score):
        if conjunctive:
            return jnp.all((tf > 0) | ~wmask) & jnp.any(wmask)
        return score > 0.0

    n_docs = idx.n_docs
    lo0, hi0 = wtbc.segment_extent(idx, jnp.int32(0), n_docs)
    tf0 = count_words_range(idx, words, lo0, hi0) * wmask
    score0 = seg_score(tf0)
    pay0 = jnp.concatenate([jnp.stack([jnp.int32(0), n_docs]), tf0])
    hp = H.make(heap_cap, 2 + Q)
    hp = H.push(hp, score0, pay0, seg_valid(tf0, score0))

    out = H.topk_make(k)
    # emission order is already globally sorted; track an explicit write cursor
    out_docs = jnp.full((k,), -1, jnp.int32)
    out_scores = jnp.full((k,), -jnp.inf, jnp.float32)

    def cond(st):
        hp, _, _, n_out, it = st
        ok = (n_out < k) & (hp.size > 0)
        if max_pops is not None:
            ok = ok & (it < max_pops)
        return ok

    def body(st):
        hp, out_docs, out_scores, n_out, it = st
        score, pay, hp = H.pop(hp)
        d0, d1 = pay[0], pay[1]
        tf = pay[2:]
        single = (d1 - d0) == 1

        # emit when single
        at = jnp.where(single, n_out, jnp.int32(0))
        out_docs = out_docs.at[at].set(jnp.where(single, d0, out_docs[at]))
        out_scores = out_scores.at[at].set(jnp.where(single, score, out_scores[at]))
        n_out = n_out + single.astype(jnp.int32)

        # split when not single (degenerate math is masked out by `enable`s)
        mid = (d0 + d1) // 2
        lo1, hi1 = wtbc.segment_extent(idx, d0, mid)
        tf1 = count_words_range(idx, words, lo1, hi1) * wmask
        tf2 = tf - tf1
        s1, s2 = seg_score(tf1), seg_score(tf2)
        pay1 = jnp.concatenate([jnp.stack([d0, mid]), tf1])
        pay2 = jnp.concatenate([jnp.stack([mid, d1]), tf2])
        hp = H.push(hp, s1, pay1, ~single & seg_valid(tf1, s1))
        hp = H.push(hp, s2, pay2, ~single & seg_valid(tf2, s2))
        return hp, out_docs, out_scores, n_out, it + 1

    hp, out_docs, out_scores, n_out, iters = jax.lax.while_loop(
        cond, body, (hp, out_docs, out_scores, jnp.int32(0), jnp.int32(0)))
    return DRResult(out_docs, out_scores, n_out, iters)


def topk_dr_batch(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
                  idf: jnp.ndarray, *, k: int, conjunctive: bool,
                  heap_cap: int, max_pops: int | None = None) -> DRResult:
    """Batched queries: ``words``/``wmask`` are (B, Q)."""
    fn = functools.partial(topk_dr, k=k, conjunctive=conjunctive,
                           heap_cap=heap_cap, max_pops=max_pops)
    return jax.vmap(lambda w, m: fn(idx, w, m, idf))(words, wmask)


# ---------------------------------------------------------------------------
# brute-force oracle (tests + benchmark ground truth)
# ---------------------------------------------------------------------------

def topk_bruteforce(idx: WTBCIndex, words, wmask, idf, *, k: int,
                    conjunctive: bool) -> DRResult:
    """Score every document directly with count_range — O(N*Q) oracle."""
    n_docs = int(idx.n_docs)
    words = jnp.asarray(words)
    wmask = jnp.asarray(wmask)
    idf_w = jnp.where(wmask, idf[words], 0.0)

    def score_doc(d):
        lo, hi = wtbc.segment_extent(idx, d, d + 1)
        tf = count_words_range(idx, words, lo, hi) * wmask
        s = jnp.dot(tf.astype(jnp.float32), idf_w)
        if conjunctive:
            ok = jnp.all((tf > 0) | ~wmask) & jnp.any(wmask)
        else:
            ok = s > 0
        return jnp.where(ok, s, -jnp.inf)

    scores = jax.lax.map(score_doc, jnp.arange(n_docs, dtype=jnp.int32))
    top_s, top_d = jax.lax.top_k(scores, k)
    found = jnp.sum(top_s > -jnp.inf).astype(jnp.int32)
    top_d = jnp.where(top_s > -jnp.inf, top_d, -1)
    return DRResult(top_d.astype(jnp.int32), top_s, found, jnp.int32(n_docs))
