"""Packed bit vectors with O(1)-amortized rank1/select1 (paper's [16] Munro).

Used by WTBC-DRB for the per-word term-frequency bitmaps
(``1 0^{tf1-1} 1 0^{tf2-1} ...``).  Layout: LSB-first bits in uint32 words,
cumulative popcount counters every ``WORDS_PER_BLOCK`` words (1024 bits =>
int32 counters cost 3.1% of the bit data).  ``lax.population_count`` maps to
the TPU VPU popcount.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

WORDS_PER_BLOCK = 32  # 1024 bits per counter block


class BitVec(NamedTuple):
    words: jnp.ndarray   # (n_words,) uint32  (padded to block multiple)
    counts: jnp.ndarray  # (n_blocks + 1,) int32 cumulative ones
    n_bits: jnp.ndarray  # () int32


def build(set_bits: np.ndarray, n_bits: int) -> BitVec:
    """Host-side: construct from sorted positions of the set bits."""
    n_words = max(1, -(-n_bits // 32))
    n_blocks = -(-n_words // WORDS_PER_BLOCK)
    n_words = n_blocks * WORDS_PER_BLOCK
    words = np.zeros(n_words, dtype=np.uint32)
    set_bits = np.asarray(set_bits, dtype=np.int64)
    np.bitwise_or.at(words, set_bits // 32, np.uint32(1) << (set_bits % 32).astype(np.uint32))
    ones_per_word = np.zeros(n_words, dtype=np.int64)
    # popcount via unpackbits on the byte view (host-side build only)
    byte_view = words.view(np.uint8).reshape(n_words, 4)
    ones_per_word = np.unpackbits(byte_view, axis=1).sum(axis=1)
    blocks = ones_per_word.reshape(n_blocks, WORDS_PER_BLOCK).sum(axis=1)
    counts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(blocks, out=counts[1:])
    assert counts[-1] == len(set_bits)
    return BitVec(
        words=jnp.asarray(words),
        counts=jnp.asarray(counts.astype(np.int32)),
        n_bits=jnp.int32(n_bits),
    )


def _masked_popcount(w: jnp.ndarray, n_valid: jnp.ndarray) -> jnp.ndarray:
    """popcount of the lowest ``n_valid`` bits of each uint32 (n_valid in [0,32])."""
    n_valid = jnp.clip(n_valid, 0, 32)
    full = jnp.uint32(0xFFFFFFFF)
    mask = jnp.where(n_valid >= 32, full,
                     (jnp.uint32(1) << n_valid.astype(jnp.uint32)) - jnp.uint32(1))
    return jax.lax.population_count(w & mask).astype(jnp.int32)


def rank1(bv: BitVec, pos: jnp.ndarray) -> jnp.ndarray:
    """Number of set bits in [0, pos)."""
    pos = jnp.clip(pos, 0, bv.n_bits).astype(jnp.int32)
    blk = pos // (WORDS_PER_BLOCK * 32)
    base = bv.counts[blk]
    chunk = jax.lax.dynamic_slice_in_dim(bv.words, blk * WORDS_PER_BLOCK, WORDS_PER_BLOCK)
    start_bit = blk * WORDS_PER_BLOCK * 32
    n_valid = pos - start_bit - jnp.arange(WORDS_PER_BLOCK, dtype=jnp.int32) * 32
    return base + jnp.sum(_masked_popcount(chunk, n_valid))


def select1(bv: BitVec, j: jnp.ndarray) -> jnp.ndarray:
    """Position of the j-th (1-based) set bit; n_bits if out of range."""
    j = j.astype(jnp.int32)
    total = bv.counts[-1]
    n_blocks = bv.counts.shape[0] - 1

    # block search: largest blk with counts[blk] < j
    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        right = bv.counts[mid] < j
        return jnp.where(right, mid, lo), jnp.where(right, hi, mid - 1)

    n_iter = max(1, int(np.ceil(np.log2(max(n_blocks, 2)))) + 1)
    blk, _ = jax.lax.fori_loop(0, n_iter, body, (jnp.int32(0), jnp.int32(n_blocks - 1)))

    chunk = jax.lax.dynamic_slice_in_dim(bv.words, blk * WORDS_PER_BLOCK, WORDS_PER_BLOCK)
    pc = jax.lax.population_count(chunk).astype(jnp.int32)
    cum = jnp.cumsum(pc)
    need = j - bv.counts[blk]
    word_i = jnp.searchsorted(cum, need, side="left").astype(jnp.int32)
    prior = jnp.where(word_i > 0, cum[jnp.maximum(word_i - 1, 0)], 0)
    w = chunk[jnp.clip(word_i, 0, WORDS_PER_BLOCK - 1)]
    # j-th set bit inside w, with j' = need - prior (1-based)
    bits = ((w >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)).astype(jnp.int32)
    bit_cum = jnp.cumsum(bits)
    bit_i = jnp.searchsorted(bit_cum, need - prior, side="left").astype(jnp.int32)
    pos = (blk * WORDS_PER_BLOCK + word_i) * 32 + bit_i
    return jnp.where((j >= 1) & (j <= total), pos, bv.n_bits).astype(jnp.int32)


# numpy oracles ---------------------------------------------------------------

def rank1_np(set_bits: np.ndarray, pos: int) -> int:
    return int(np.count_nonzero(np.asarray(set_bits) < pos))


def select1_np(set_bits: np.ndarray, j: int, n_bits: int) -> int:
    sb = np.sort(np.asarray(set_bits))
    return int(sb[j - 1]) if 1 <= j <= len(sb) else n_bits
