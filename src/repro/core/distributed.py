"""Document-sharded distributed retrieval (DESIGN.md §4).

The paper's own deployment motivation is "a cluster that implements a large
in-memory distributed index".  We realize it the way production engines do —
document partitioning:

* the (s,c)-DC model is fitted once on **global** frequencies (codewords must
  agree across shards),
* each device along the sharding mesh axes holds a full WTBC over its own
  contiguous document range (shapes padded to the max shard so the stacked
  index is one rectangular pytree),
* a query is replicated, solved locally with the *identical* single-host
  kernels (`topk_dr` / `topk_drb_*`), and per-shard top-k lists are merged
  with one ``all_gather`` of (k,) floats+ints per shard followed by a local
  ``lax.top_k`` — the only cross-shard communication in the system.

Scoring uses the **global** idf table (replicated, V floats) so shard results
are directly comparable; per-shard `df` remains local (it drives DRB cursor
initialization only).

Straggler mitigation hook: `topk_dr` is an any-time algorithm — the
``max_pops`` budget bounds per-shard work; a budget-limited shard returns its
current best list and the merge remains correct for all documents examined
(EXPERIMENTS.md §Perf quantifies the exactness/latency trade).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import drb as drb_mod
from repro.core import ranked, scdc, wtbc
from repro.core.drb import DRBAux
from repro.core.wtbc import WTBCIndex


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: the top-level export (with its
    ``check_vma`` knob) landed after 0.4.x; older releases ship it as
    ``jax.experimental.shard_map`` with the knob spelled ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("idx", "aux", "doc_base", "global_df", "global_idf",
                 "global_avg_dl"),
    meta_fields=("n_shards",))
@dataclasses.dataclass(frozen=True)
class ShardedWTBC:
    """Stacked (leading shard axis) per-shard indexes + global scoring tables."""
    idx: WTBCIndex          # every leaf has leading dim n_shards
    aux: DRBAux | None      # stacked DRB bitmaps (or None)
    doc_base: jnp.ndarray   # (n_shards,) int32 global docid of shard's doc 0
    global_df: jnp.ndarray  # (V,) int32 global document frequency per rank
    global_idf: jnp.ndarray # (V,) float32 (tf-idf form; other measures can
                            # derive their own table from global_df)
    global_avg_dl: jnp.ndarray  # () float32 (BM25 length normalization)
    n_shards: int


def _pad_to(a: np.ndarray, n: int, fill) -> np.ndarray:
    if a.shape[0] == n:
        return a
    pad = np.full((n - a.shape[0],) + a.shape[1:], fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=0)


def _stack_bytemaps(maps) -> "wtbc.ByteMap":
    from repro.core.bytemap import ByteMap
    max_data = max(m.data.shape[0] for m in maps)
    max_blocks = max(m.counts.shape[0] for m in maps)
    datas, counts, lengths = [], [], []
    for m in maps:
        d = np.asarray(m.data)
        c = np.asarray(m.counts)
        datas.append(_pad_to(d, max_data, 0))
        # pad counter rows by repeating the final cumulative row: select's
        # binary search stays correct past the logical end
        if c.shape[0] < max_blocks:
            c = np.concatenate([c, np.repeat(c[-1:], max_blocks - c.shape[0], axis=0)])
        counts.append(c)
        lengths.append(np.asarray(m.length))
    return ByteMap(data=jnp.asarray(np.stack(datas)),
                   counts=jnp.asarray(np.stack(counts)),
                   length=jnp.asarray(np.stack(lengths)),
                   block=maps[0].block)


def build_sharded(doc_tokens: list[np.ndarray], vocab_size: int, n_shards: int,
                  block: int = 4096, with_drb: bool = True,
                  eps: float = 1e-6) -> tuple[ShardedWTBC, scdc.SCDCModel]:
    """Fit global codes, build + stack per-shard indexes (host side)."""
    n_docs = len(doc_tokens)
    doc_len = np.array([len(d) for d in doc_tokens], dtype=np.int64)
    flat = np.concatenate([np.concatenate([d, [0]]) for d in doc_tokens])
    freqs = np.bincount(flat, minlength=vocab_size)
    model = scdc.fit(freqs, reserve_first=0)

    # contiguous document ranges, balanced by token count
    tokens_cum = np.cumsum(doc_len + 1)
    targets = (np.arange(1, n_shards) * tokens_cum[-1]) // n_shards
    cuts = np.searchsorted(tokens_cum, targets).tolist()
    bounds = [0] + [c + 1 for c in cuts] + [n_docs]
    bounds = sorted(set(bounds))
    while len(bounds) < n_shards + 1:          # degenerate tiny corpora
        bounds.append(n_docs)
    shard_docs = [doc_tokens[bounds[i]:bounds[i + 1]] for i in range(n_shards)]
    for sd in shard_docs:
        if not sd:
            raise ValueError("a shard received zero documents; lower n_shards")

    # global document frequencies -> global idf and global stopword decision
    df_global = np.zeros(vocab_size, dtype=np.int64)
    for sd in shard_docs:
        for d in sd:
            df_global[np.unique(model.rank_of_word[d])] += 1
    idf_np = np.log(n_docs / np.maximum(df_global, 1)).astype(np.float32)
    idf_np[wtbc.SEP_RANK] = 0.0
    has_bm_global = (idf_np >= eps) & (df_global > 0)

    shards = [wtbc.build_index_with_model(sd, model, block) for sd in shard_docs]
    auxes = ([drb_mod.build_aux(s, model, sd, eps, has_bm_override=has_bm_global)
              for s, sd in zip(shards, shard_docs)]
             if with_drb else None)
    doc_base = np.asarray(bounds[:-1], dtype=np.int32)

    # --- stack index leaves, padding ragged dimensions ------------------------
    max_docs = max(int(s.n_docs) for s in shards)
    levels = tuple(_stack_bytemaps([s.levels[L] for s in shards])
                   for L in range(wtbc.MAX_LEVELS))
    offsets = tuple(jnp.asarray(np.stack([np.asarray(s.offsets[L]) for s in shards]))
                    for L in range(wtbc.MAX_LEVELS))

    def stk(get, pad_fill=None, pad_len=None):
        arrs = [np.asarray(get(s)) for s in shards]
        if pad_len is not None:
            arrs = [_pad_to(a, pad_len, pad_fill) for a in arrs]
        return jnp.asarray(np.stack(arrs))

    big_n = int(max(int(s.n) for s in shards))
    idx = WTBCIndex(
        levels=levels, offsets=offsets,
        cw=stk(lambda s: s.cw), cw_len=stk(lambda s: s.cw_len),
        node_off=stk(lambda s: s.node_off), base_rank=stk(lambda s: s.base_rank),
        sep_pos=stk(lambda s: s.sep_pos, pad_fill=big_n, pad_len=max_docs),
        df=stk(lambda s: s.df), occ=stk(lambda s: s.occ),
        doc_len=stk(lambda s: s.doc_len, pad_fill=0, pad_len=max_docs),
        n=stk(lambda s: s.n), n_docs=stk(lambda s: s.n_docs),
        s=model.s, c=model.c)

    aux = None
    if with_drb:
        from repro.core.bitvec import BitVec
        max_words = max(a.bv.words.shape[0] for a in auxes)
        max_blocks = max(a.bv.counts.shape[0] for a in auxes)
        words_, counts_, nbits_, offs_, hasbm_ = [], [], [], [], []
        for a in auxes:
            w = _pad_to(np.asarray(a.bv.words), max_words, 0)
            c_ = np.asarray(a.bv.counts)
            if c_.shape[0] < max_blocks:
                c_ = np.concatenate([c_, np.repeat(c_[-1:], max_blocks - c_.shape[0], axis=0)])
            words_.append(w); counts_.append(c_)
            nbits_.append(np.asarray(a.bv.n_bits))
            offs_.append(np.asarray(a.bit_off)); hasbm_.append(np.asarray(a.has_bm))
        aux = DRBAux(
            bv=BitVec(words=jnp.asarray(np.stack(words_)),
                      counts=jnp.asarray(np.stack(counts_)),
                      n_bits=jnp.asarray(np.stack(nbits_))),
            bit_off=jnp.asarray(np.stack(offs_)),
            has_bm=jnp.asarray(np.stack(hasbm_)),
            eps=eps)

    avg_dl = np.float32(doc_len.sum() / max(n_docs, 1))
    sharded = ShardedWTBC(idx=idx, aux=aux, doc_base=jnp.asarray(doc_base),
                          global_df=jnp.asarray(df_global.astype(np.int32)),
                          global_idf=jnp.asarray(idf_np),
                          global_avg_dl=jnp.asarray(avg_dl), n_shards=n_shards)
    return sharded, model


# ---------------------------------------------------------------------------
# distributed query (shard_map + all_gather merge)
# ---------------------------------------------------------------------------

def distributed_topk(sharded: ShardedWTBC, words: jnp.ndarray, wmask: jnp.ndarray,
                     *, k: int, method: str, mesh: Mesh,
                     shard_axes: str | tuple[str, ...],
                     heap_cap: int | None = None,
                     max_df_cap: int = 256,
                     max_pops: int | None = None,
                     measure=None,
                     idf: jnp.ndarray | None = None,
                     beam_width: int = 1) -> ranked.DRResult:
    """Run a top-k query over the sharded index under ``mesh``.

    method: 'dr-and' | 'dr-or' | 'drb-and' | 'drb-or'.
    shard_axes: mesh axis (or axes tuple) the documents are sharded over; the
    total device count along them must equal ``sharded.n_shards``.
    max_pops: per-shard any-time budget for the loop cores (DR and DRB-AND;
    straggler mitigation, see module docstring); None = run each shard to
    completion.  The merged result carries global anytime metadata
    (DESIGN.md §11): the global pending bound is the max over the shards'
    bounds, and a merged slot is certified iff its score *strictly* beats
    that bound — strict because a score tie across shards could hide a
    lower-doc-id tie winner behind another shard's frontier (conservative:
    a certified-at-a-tie local slot may come back uncertified merged).
    idf: (V,) replicated scoring table; defaults to ``sharded.global_idf``
    (tf-idf form).  Pass a measure-specific table (derivable from
    ``sharded.global_df``) so shard scores match the single-host backend.
    beam_width: per-shard frontier width for the DR / DRB-AND loop cores
    (DESIGN.md §6); each shard runs the identical beam the single-host
    backend would.
    """
    from repro.core import scoring
    measure = measure or scoring.TfIdf()
    axes = (shard_axes,) if isinstance(shard_axes, str) else tuple(shard_axes)
    if heap_cap is None:
        heap_cap = 2 * int(np.max(np.asarray(sharded.idx.n_docs))) + 4
    if idf is None:
        idf = sharded.global_idf

    spec_shard = P(axes if len(axes) > 1 else axes[0])
    sharded_specs = ShardedWTBC(
        idx=jax.tree.map(lambda _: spec_shard, sharded.idx),
        aux=(jax.tree.map(lambda _: spec_shard, sharded.aux)
             if sharded.aux is not None else None),
        doc_base=spec_shard,
        global_df=P(),                # replicated scoring tables
        global_idf=P(),
        global_avg_dl=P(),
        n_shards=sharded.n_shards)
    in_specs = (sharded_specs, P(), P(), P())
    # drb-or is the one loop-free method whose core reports no pad-waste
    # lane count; every other method threads `padded` through the merge so
    # the serving/obs layer sees the same diagnostics sharded as single-host
    has_pad = method != "drb-or"
    out_specs = (P(),) * (9 if has_pad else 8)

    def local(sh: ShardedWTBC, words, wmask, idf_tab):
        batched = words.ndim == 2                      # (B, Q) query batches
        idx = jax.tree.map(lambda x: x[0], sh.idx)

        def one(words1, wmask1):
            if method == "dr-and" or method == "dr-or":
                return ranked.topk_dr(idx, words1, wmask1, idf_tab,
                                      k=k, conjunctive=(method == "dr-and"),
                                      heap_cap=heap_cap, max_pops=max_pops,
                                      beam_width=beam_width)
            aux = jax.tree.map(lambda x: x[0], sh.aux)
            if method == "drb-and":
                return drb_mod.topk_drb_and(idx, aux, words1, wmask1, measure,
                                            k=k, idf=idf_tab,
                                            avg_dl=sh.global_avg_dl,
                                            beam_width=beam_width,
                                            max_pops=max_pops)
            if method == "drb-or":
                return drb_mod.topk_drb_or(idx, aux, words1, wmask1, measure,
                                           k=k, max_df_cap=max_df_cap,
                                           idf=idf_tab,
                                           avg_dl=sh.global_avg_dl)
            raise ValueError(method)

        if batched:
            if method in ("dr-and", "dr-or"):
                # the explicitly batched core, NOT vmap(one): under vmap the
                # active-frontier lax.switch index is batched, which executes
                # EVERY bucket body per trip and selects; topk_dr_batch
                # hoists a scalar dispatch above the vmapped row body
                # (bitwise-equal leaves — see core/ranked.py)
                res = ranked.topk_dr_batch(
                    idx, words, wmask, idf_tab, k=k,
                    conjunctive=(method == "dr-and"), heap_cap=heap_cap,
                    max_pops=max_pops, beam_width=beam_width)
            else:
                res = jax.vmap(one)(words, wmask)     # leaves (B, k)
        else:
            res = one(words, wmask)
        gdocs = jnp.where(res.docs >= 0, res.docs + sh.doc_base[0], -1)
        all_d, all_s = gdocs, res.scores               # (B?, k)
        for ax in axes:
            # gather shard axis then fold it into the candidate axis
            all_d = jnp.moveaxis(jax.lax.all_gather(all_d, ax), 0, -2)
            all_s = jnp.moveaxis(jax.lax.all_gather(all_s, ax), 0, -2)
            all_d = all_d.reshape(*all_d.shape[:-2], -1)
            all_s = all_s.reshape(*all_s.shape[:-2], -1)
        # (k+1)-wide merge: slot k's score is the best candidate the merge
        # DROPS — a known document not in the result, folded into the
        # reported bound below.  top_k tie-breaks toward the earliest
        # gathered index = the smallest global doc id (shard blocks are
        # doc-ordered and so is each shard's list), matching the
        # single-host tie order, so a dropped tie-loser always ranks after
        # every retained slot.
        kk = min(k + 1, all_s.shape[-1])
        top_s, ti = jax.lax.top_k(all_s, kk)
        dropped_s = (top_s[..., k] if kk > k
                     else jnp.full(top_s.shape[:-1], -jnp.inf, jnp.float32))
        top_s, ti = top_s[..., :k], ti[..., :k]
        top_d = jnp.take_along_axis(all_d, ti, axis=-1)
        n_found = jnp.sum(top_s > -jnp.inf, axis=-1).astype(jnp.int32)
        # work metrics sum over shards; overflow is any-shard; the pending
        # bound is max-over-shards (a hidden doc on any shard is bounded by
        # its own shard's pending threshold)
        iters, pops, over = res.iters, res.pops, res.overflowed.astype(jnp.int32)
        padded, bound = res.padded, res.bound
        for ax in axes:
            iters = jax.lax.psum(iters, ax)
            pops = jax.lax.psum(pops, ax)
            over = jax.lax.psum(over, ax)
            bound = jax.lax.pmax(bound, ax)
            if has_pad:
                padded = jax.lax.psum(padded, ax)
        # certification is strict-score vs the global *pending* bound (see
        # the docstring); the reported bound additionally covers the docs
        # the merge itself dropped
        certified = ((top_s > bound[..., None])
                     & ~(over > 0)[..., None] & (top_s > -jnp.inf))
        bound_out = jnp.maximum(bound, dropped_s)
        out = (jnp.where(top_s > -jnp.inf, top_d, -1), top_s, n_found, iters,
               pops, over > 0, certified, bound_out)
        return out + (padded,) if has_pad else out

    fn = _shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    res = fn(sharded, words, wmask, idf)
    docs, scores, n_found, iters, pops, over, certified, bound = res[:8]
    return ranked.DRResult(docs, scores, n_found, iters, pops, over,
                           padded=res[8] if has_pad else None,
                           certified=certified, bound=bound)
