"""rank/select/count over byte sequences — the WTBC's core primitive.

The paper keeps *partial counters* per bytemap so that ``rank_b(B, i)`` /
``select_b(B, i)`` run in microseconds at ~3% space overhead.  TPU-native
realization (see DESIGN.md §2):

* one cumulative count matrix ``counts[(n_blocks+1), 256] int32`` sampled every
  ``block`` bytes (``block = 32768`` reproduces the paper's 3% overhead at
  int32 counters; tests use smaller blocks),
* the in-block residual is a masked compare-and-sum over a single block that
  lives in VMEM on TPU — the ``kernels/byte_rank`` Pallas kernel fuses the
  counter gather with that reduce; this module is the pure-jnp reference path
  (also used directly on CPU),
* ``select`` is a binary search over one counter column plus an in-block
  prefix scan — no extra space beyond the same counters.

Build is numpy (host), queries are jit/vmap-friendly jnp.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = 4096  # bytes per counter block (power of two)


@partial(jax.tree_util.register_dataclass,
         data_fields=("data", "counts", "length"), meta_fields=("block",))
@dataclasses.dataclass(frozen=True)
class ByteMap:
    """A byte sequence + rank/select acceleration counters.

    The stored array is zero-padded to a multiple of ``block``; ``length`` is
    the logical length.  ``counts[k, v]`` = occurrences of byte ``v`` in
    ``data[0 : k*block]`` (exclusive prefix).  ``block`` is static metadata.
    """

    data: jnp.ndarray    # (padded_n,) uint8
    counts: jnp.ndarray  # (n_blocks + 1, 256) int32 cumulative
    length: jnp.ndarray  # () int32
    block: int           # static

    @property
    def n_blocks(self) -> int:
        return self.counts.shape[0] - 1


def build(data: np.ndarray, block: int = DEFAULT_BLOCK) -> ByteMap:
    """Host-side construction of the counter structure."""
    data = np.ascontiguousarray(data, dtype=np.uint8)
    n = len(data)
    n_blocks = max(1, -(-n // block))
    padded = np.zeros(n_blocks * block, dtype=np.uint8)
    padded[:n] = data
    # per-block histograms -> exclusive cumulative sums (single vectorized pass)
    flat_keys = (np.arange(n_blocks * block, dtype=np.int64) // block) * 256 + padded
    hist = np.bincount(flat_keys, minlength=n_blocks * 256).reshape(n_blocks, 256)
    # padding bytes are zeros; remove them from the last block's histogram so
    # counters reflect the logical sequence only
    hist[-1, 0] -= n_blocks * block - n
    counts = np.zeros((n_blocks + 1, 256), dtype=np.int64)
    np.cumsum(hist, axis=0, out=counts[1:])
    if counts.max() >= 2**31:
        raise ValueError("sequence too long for int32 counters")
    return ByteMap(
        data=jnp.asarray(padded),
        counts=jnp.asarray(counts.astype(np.int32)),
        length=jnp.int32(n),
        block=block,
    )


# ---------------------------------------------------------------------------
# rank / count
# ---------------------------------------------------------------------------

def rank(bm: ByteMap, byte: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """occurrences of ``byte`` in ``data[0:pos]`` (pos in [0, length]).

    The in-block residual uses a hierarchical scan (512-byte sub-chunks) so
    the index/mask vectors are (block/512,) + (512,) instead of a full
    block-length int32 iota — 4x less traffic at block=32768 (§Perf)."""
    pos = jnp.clip(pos, 0, bm.length)
    blk = pos // bm.block
    base = bm.counts[blk, byte]
    chunk = jax.lax.dynamic_slice_in_dim(bm.data, blk * bm.block, bm.block)
    off = pos - blk * bm.block
    sub = 512 if bm.block >= 512 else bm.block
    n_sub = bm.block // sub
    hits2d = chunk.reshape(n_sub, sub) == byte.astype(jnp.uint8)
    per_sub = jnp.sum(hits2d, axis=1, dtype=jnp.int32)
    sub_i = off // sub
    full = jnp.sum(jnp.where(jnp.arange(n_sub, dtype=jnp.int32) < sub_i,
                             per_sub, 0), dtype=jnp.int32)
    subchunk = jax.lax.dynamic_slice_in_dim(
        chunk, jnp.clip(sub_i, 0, n_sub - 1) * sub, sub)
    partial = jnp.sum((subchunk == byte.astype(jnp.uint8))
                      & (jnp.arange(sub, dtype=jnp.int32) < off - sub_i * sub),
                      dtype=jnp.int32)
    return base + full + partial


def rank_block_base(bm: ByteMap, byte: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Counter-only part of rank (used by callers that fuse the residual)."""
    blk = jnp.clip(pos, 0, bm.length) // bm.block
    return bm.counts[blk, byte]


def count_range(bm: ByteMap, byte: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """occurrences of ``byte`` in ``data[lo:hi]``."""
    return rank(bm, byte, hi) - rank(bm, byte, lo)


# ---------------------------------------------------------------------------
# select
# ---------------------------------------------------------------------------

def select(bm: ByteMap, byte: jnp.ndarray, j: jnp.ndarray) -> jnp.ndarray:
    """Position of the ``j``-th (1-based) occurrence of ``byte``; length if absent.

    Binary search the counter column for the block containing the j-th
    occurrence, then prefix-scan that block.  O(log n_blocks) gathers + one
    block scan, the same acceleration the paper gets from partial counters.
    """
    j = j.astype(jnp.int32)
    col_total = bm.counts[-1, byte]

    # largest blk with counts[blk, byte] < j  ->  binary search on the column.
    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        go_right = bm.counts[mid, byte] < j
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid - 1)

    n_blocks = bm.counts.shape[0] - 1
    n_iter = max(1, int(np.ceil(np.log2(max(n_blocks, 2)))) + 1)
    lo, _ = jax.lax.fori_loop(0, n_iter, body, (jnp.int32(0), jnp.int32(n_blocks - 1)))

    base = bm.counts[lo, byte]
    chunk = jax.lax.dynamic_slice_in_dim(bm.data, lo * bm.block, bm.block)
    need = j - base
    # hierarchical in-block scan: a flat int32 cumsum over a 32 KB block costs
    # 4x the block in write traffic; instead reduce 512-byte sub-chunks to a
    # (block/512,) count vector, pick the sub-chunk, and scan only 512 bytes
    # (§Perf hillclimb 3 — same trick a TPU kernel would do in VMEM).
    sub = 512 if bm.block >= 512 else bm.block
    n_sub = bm.block // sub
    hits2d = (chunk.reshape(n_sub, sub) == byte.astype(jnp.uint8))
    per_sub = jnp.cumsum(jnp.sum(hits2d, axis=1, dtype=jnp.int32))
    sub_i = jnp.searchsorted(per_sub, need, side="left").astype(jnp.int32)
    prior = jnp.where(sub_i > 0, per_sub[jnp.maximum(sub_i - 1, 0)], 0)
    subchunk = jax.lax.dynamic_slice_in_dim(
        chunk, jnp.clip(sub_i, 0, n_sub - 1) * sub, sub)
    cums = jnp.cumsum((subchunk == byte.astype(jnp.uint8)).astype(jnp.int32))
    idx = jnp.searchsorted(cums, need - prior, side="left")
    pos = lo * bm.block + jnp.clip(sub_i, 0, n_sub - 1) * sub + idx
    return jnp.where((j >= 1) & (j <= col_total), pos, bm.length).astype(jnp.int32)


def access(bm: ByteMap, pos: jnp.ndarray) -> jnp.ndarray:
    """data[pos] (uint8)."""
    return bm.data[jnp.clip(pos, 0, bm.length - 1)]


# ---------------------------------------------------------------------------
# numpy oracles (used by tests and the ref.py kernel oracles)
# ---------------------------------------------------------------------------

def rank_np(data: np.ndarray, byte: int, pos: int) -> int:
    return int(np.count_nonzero(data[:pos] == byte))


def select_np(data: np.ndarray, byte: int, j: int) -> int:
    occ = np.flatnonzero(data == byte)
    return int(occ[j - 1]) if 1 <= j <= len(occ) else len(data)
