"""Positional ranked retrieval over the WTBC: phrase and proximity queries.

The paper exploits the WTBC's ``locate``/``select`` machinery only for ranked
conjunctive/disjunctive queries.  This module extends the *same structure* —
at zero extra space — to two positional workloads in the spirit of the
wavelet-tree positional algorithms of Gagie–Navarro–Puglisi ("New Algorithms
on Wavelet Trees and Applications to Information Retrieval"):

* **phrase**: the query words must occur *consecutively, in order*.  The
  rarest query word anchors the scan: for each of its occurrences (one
  ``locate`` walk each) the candidate phrase start is checked by decoding the
  neighbouring root positions (one ``decode_at`` walk per query word) — no
  materialized text, no per-doc position buffers, O(occ_min · Q) tree walks.
* **near** (proximity): every query word must occur inside some window of at
  most ``window`` consecutive tokens of a document.  A Q-way cursor merge
  enumerates all query-word occurrences in text order (one ``locate`` per
  step) and runs the classical minimal-cover sweep: at each occurrence the
  best window ending there spans back to the *oldest* last-seen occurrence
  among the query words, so the per-document minimal window falls out of one
  O(Σ occ_w) pass.

Both modes score documents with any additive per-word measure (tf-idf, BM25):
phrase scores use the phrase tf for every query word (a phrase behaves as a
single virtual term weighted by its words' idfs); near scores use the full
per-document tf vector, with the window acting as an eligibility filter.
Results carry match positions (doc-relative start of the first phrase match /
of the minimal window) so callers can highlight without storing text.

Everything is jit/vmap-compatible: ``topk_positional`` is one jitted program,
``topk_positional_batch`` is its vmap over (B, Q) query batches, mirroring
``ranked.topk_dr`` / ``topk_dr_batch``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import wtbc
from repro.core.wtbc import WTBCIndex

INT32_MAX = jnp.int32(2**31 - 1)


class PositionalResult(NamedTuple):
    docs: jnp.ndarray       # (k,) int32, -1 padded, descending score
    scores: jnp.ndarray     # (k,) float32, -inf padded
    n_found: jnp.ndarray    # () int32
    iters: jnp.ndarray      # () int32 — occurrence-scan steps (work metric)
    match_pos: jnp.ndarray  # (k,) int32 doc-relative match start, -1 padded
    match_len: jnp.ndarray  # (k,) int32 match width in tokens, -1 padded


def query_offsets(wmask: jnp.ndarray) -> jnp.ndarray:
    """Offset of each valid slot within the phrase (position among the valid
    slots, in slot order); garbage for invalid slots — mask before use."""
    return jnp.cumsum(wmask.astype(jnp.int32)) - 1


def doc_positions(idx: WTBCIndex, w: jnp.ndarray, d: jnp.ndarray,
                  cap: int) -> jnp.ndarray:
    """Doc-relative positions of word-rank ``w``'s occurrences in document
    ``d``, -1 padded to the static ``cap`` (per-document occurrence-position
    extraction: one count + one ``locate`` per occurrence)."""
    lo, hi = wtbc.segment_extent(idx, d, d + 1)
    # both counts in one batched descent (the beam cores' rank entry point)
    cnt = wtbc.count_range_batch(idx, jnp.stack([w, w]),
                                 jnp.stack([jnp.int32(0), lo]),
                                 jnp.stack([lo, hi]))
    before, tf = cnt[0], cnt[1]
    js = jnp.arange(cap, dtype=jnp.int32)
    pos = jax.vmap(
        lambda j: wtbc.locate(idx, w, before + jnp.minimum(j, tf - 1) + 1))(js)
    return jnp.where(js < tf, pos - lo, -1)


# ---------------------------------------------------------------------------
# phrase: anchor scan on the rarest word + decode adjacency check
# ---------------------------------------------------------------------------

def phrase_tables(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-document phrase term frequency and first match position.

    Returns ``(tf (N,), first_pos (N,), iters)`` where ``tf[d]`` counts exact
    occurrences of the phrase formed by the valid slots of ``words`` (in slot
    order) inside document ``d`` and ``first_pos[d]`` is the doc-relative
    start of the first one (-1 when none).  Duplicate query words are handled
    naturally — adjacency is checked against the decoded text itself.
    """
    N = idx.sep_pos.shape[0]
    offs = query_offsets(wmask)
    q_len = jnp.sum(wmask.astype(jnp.int32))
    occ_w = jnp.where(wmask, idx.occ[words], INT32_MAX)
    qstar = jnp.argmin(occ_w)
    wstar = words[qstar]
    ostar = offs[qstar]
    n_anchor = jnp.where(jnp.any(wmask), idx.occ[wstar], 0)

    tf0 = jnp.zeros((N + 1,), jnp.int32)
    first0 = jnp.full((N + 1,), INT32_MAX, jnp.int32)

    def cond(st):
        j, _, _ = st
        return j <= n_anchor

    def body(st):
        j, tf, first = st
        p = wtbc.locate(idx, wstar, j)
        start = p - ostar
        d = wtbc.doc_of_pos(idx, p)
        lo = wtbc.doc_start(idx, d)
        hi = wtbc.doc_end(idx, d)
        inb = (start >= lo) & (start + q_len <= hi)
        slot_pos = jnp.clip(start + offs, 0, idx.n - 1)
        dec = jax.vmap(lambda pp: wtbc.decode_at(idx, pp))(slot_pos)
        match = inb & jnp.all(~wmask | (dec == words))
        at = jnp.where(match, jnp.minimum(d, N), N)
        tf = tf.at[at].add(1)
        first = first.at[at].min(start - lo)
        return j + 1, tf, first

    iters, tf, first = jax.lax.while_loop(
        cond, body, (jnp.int32(1), tf0, first0))
    tf, first = tf[:N], first[:N]
    return tf, jnp.where(tf > 0, first, -1), iters - 1


# ---------------------------------------------------------------------------
# near: Q-way occurrence merge + minimal-cover sweep
# ---------------------------------------------------------------------------

def near_tables(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-document tf vector and minimal cover window over the query words.

    Returns ``(tf (Q, N), min_win (N,), win_pos (N,), iters)``: ``min_win[d]``
    is the width (in tokens) of the smallest window of document ``d``
    containing at least one occurrence of every valid query word (INT32_MAX
    when no such window exists), ``win_pos[d]`` its doc-relative start (-1
    when none).  One text-order sweep over all query-word occurrences: at each
    occurrence the candidate window spans back to the oldest last-seen
    occurrence among the words, which is the classical exact minimal-cover
    recurrence.  Ties (equal width) resolve to the leftmost window.
    """
    Q = words.shape[0]
    N = idx.sep_pos.shape[0]
    occ_w = jnp.where(wmask, idx.occ[words], 0)
    absent = jnp.any(wmask & (occ_w == 0))

    j0 = jnp.ones((Q,), jnp.int32)
    p_first = jax.vmap(lambda w: wtbc.locate(idx, w, jnp.int32(1)))(words)
    p0 = jnp.where(wmask & (occ_w > 0) & ~absent, p_first, INT32_MAX)
    last0 = jnp.full((Q,), -1, jnp.int32)
    tf0 = jnp.zeros((Q, N + 1), jnp.int32)
    win0 = jnp.full((N + 1,), INT32_MAX, jnp.int32)
    pos0 = jnp.full((N + 1,), -1, jnp.int32)

    def cond(st):
        _, p, *_ = st
        return jnp.min(p) < INT32_MAX

    def body(st):
        j, p, last, tf, win, pos, it = st
        qm = jnp.argmin(p)
        pm = p[qm]
        last = last.at[qm].set(pm)
        d = jnp.minimum(wtbc.doc_of_pos(idx, pm), N)
        lo = wtbc.doc_start(idx, jnp.minimum(d, idx.n_docs - 1))
        tf = tf.at[qm, d].add(1)
        covered = jnp.all(~wmask | (last >= lo))
        wstart = jnp.min(jnp.where(wmask, last, INT32_MAX))
        width = pm - wstart + 1
        better = covered & (width < win[d])
        win = win.at[d].set(jnp.where(better, width, win[d]))
        pos = pos.at[d].set(jnp.where(better, wstart - lo, pos[d]))
        jn = j[qm] + 1
        pn = jnp.where(jn <= idx.occ[words[qm]],
                       wtbc.locate(idx, words[qm], jn), INT32_MAX)
        return (j.at[qm].set(jn), p.at[qm].set(pn), last, tf, win, pos,
                it + 1)

    j, p, last, tf, win, pos, iters = jax.lax.while_loop(
        cond, body, (j0, p0, last0, tf0, win0, pos0, jnp.int32(0)))
    return tf[:, :N], win[:N], pos[:N], iters


# ---------------------------------------------------------------------------
# ranked top-k entry points (mirror ranked.topk_dr / topk_dr_batch)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "phrase", "measure"))
def topk_positional(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
                    idf: jnp.ndarray, *, k: int, phrase: bool, measure,
                    window: jnp.ndarray | int | None = None,
                    avg_dl: jnp.ndarray | None = None) -> PositionalResult:
    """Ranked positional top-k.  ``words`` (Q,) word-ranks, ``wmask`` (Q,)
    valid-slot mask (valid slots form a prefix), ``idf`` (V,) the measure's
    idf table.

    phrase=True:  exact consecutive in-order match of the valid words; a
                  document's tf is its phrase-occurrence count and every
                  query word is scored with it.
    phrase=False: proximity — eligible documents have a minimal cover window
                  of width <= ``window`` (required); scores use the full
                  per-document tf vector.
    """
    N = idx.sep_pos.shape[0]
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)
    if avg_dl is None:
        avg_dl = (jnp.sum(idx.doc_len.astype(jnp.float32))
                  / idx.n_docs.astype(jnp.float32))

    if phrase:
        tf_phrase, first_pos, iters = phrase_tables(idx, words, wmask)
        tf_mat = tf_phrase[:, None] * wmask          # (N, Q)
        eligible = tf_phrase > 0
        match_pos = first_pos
        match_len = jnp.full((N,), jnp.sum(wmask.astype(jnp.int32)), jnp.int32)
    else:
        if window is None:
            raise ValueError("proximity search requires a window")
        tf_q, min_win, win_pos, iters = near_tables(idx, words, wmask)
        tf_mat = tf_q.T * wmask                      # (N, Q)
        eligible = min_win <= jnp.asarray(window, jnp.int32)
        match_pos = win_pos
        match_len = jnp.where(min_win < INT32_MAX, min_win, -1)

    scores = measure.score(tf_mat, idf_w, idx.doc_len, avg_dl)
    scores = jnp.where(eligible, scores, -jnp.inf)
    top_s, top_d = jax.lax.top_k(scores, k)
    found = jnp.sum(top_s > -jnp.inf).astype(jnp.int32)
    ok = top_s > -jnp.inf
    return PositionalResult(
        docs=jnp.where(ok, top_d, -1).astype(jnp.int32),
        scores=top_s.astype(jnp.float32),
        n_found=found,
        iters=iters,
        match_pos=jnp.where(ok, match_pos[top_d], -1),
        match_len=jnp.where(ok, match_len[top_d], -1),
    )


def topk_positional_batch(idx: WTBCIndex, words: jnp.ndarray,
                          wmask: jnp.ndarray, idf: jnp.ndarray, *, k: int,
                          phrase: bool, measure,
                          window: jnp.ndarray | int | None = None,
                          avg_dl: jnp.ndarray | None = None) -> PositionalResult:
    """Batched positional queries: ``words``/``wmask`` are (B, Q)."""
    fn = functools.partial(topk_positional, k=k, phrase=phrase,
                           measure=measure, window=window, avg_dl=avg_dl)
    return jax.vmap(lambda w, m: fn(idx, w, m, idf))(words, wmask)
