"""Fixed-capacity array binary heaps, jit-compatible (lax.while_loop sifts).

Algorithm 1 of the paper is driven by a priority queue of text segments.  A
pointer-based heap does not exist in JAX-land; we use the classical implicit
binary heap over a pre-allocated score array plus an int32 payload matrix.
Pushes/pops are O(log cap) with dynamic index updates — the whole retrieval
loop stays on-device with no host round trips.

All operations take and return the state tuple
``(scores, payload, size, overflowed)``:
  scores     (cap,)   float32, max-heap ordered prefix [0, size)
  payload    (cap, P) int32
  size       ()       int32
  overflowed ()       bool — any enabled push ever hit a full heap

**Total priority order.**  The heap orders elements by the lexicographic key
``(score desc, payload[0] asc, payload[1] desc)`` (with the payload columns
dropped for narrower payloads).  Algorithm 1 stores segments ``[d0, d1)`` as
``payload[:2]``, and distinct segments always have distinct keys — so the
order is *total*: pop order does not depend on insertion order, and therefore
not on the beam width or batching schedule that produced the insertions.
Score ties (duplicate tf patterns across documents) resolve toward the lower
``d0``, matching ``lax.top_k`` / ``TopK`` doc-id tie-breaking, so every layer
of the stack agrees on tie order (DESIGN.md §8).

``enable`` flags make pushes/pops conditional without ``lax.cond`` branches on
the large state (disabled ops are no-ops with the same cost).

A push against a full heap *drops the element* (the search stays total but may
become inexact); ``overflowed`` latches that event so callers — `DRResult` /
`SearchResults.diagnostics` — can surface it instead of silently returning
corrupted rankings (DESIGN.md §6).

``pop_p`` / ``push_many`` are the frontier-batched (beam) entry points: P
ordered pops and a bulk reinsert per search iteration, so Algorithm 1's rank
workload can be batched P-wide between heap interactions (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)
INT32_MAX = jnp.int32(2**31 - 1)
INT32_MIN = jnp.int32(-2**31)


def lex_gt(sa, a0, a1, sb, b0, b1):
    """Strict elementwise comparison in the total priority order
    ``(score desc, d0 asc, d1 desc)``: True where key A precedes key B."""
    return (sa > sb) | ((sa == sb) & ((a0 < b0) | ((a0 == b0) & (a1 > b1))))


def lex_argmax(s, d0, d1, valid):
    """Index (last axis) of the lex-greatest valid ``(s, d0, d1)`` entry:
    max score, then min d0 among score ties, then max d1.  Three masked
    reductions — the dense-pool analogue of a heap top (core/mega.py).
    All-invalid rows return index 0; callers mask with ``valid.any()``."""
    s_ = jnp.where(valid, s, NEG_INF)
    c = valid & (s_ == jnp.max(s_, axis=-1, keepdims=True))
    d0_ = jnp.where(c, d0, INT32_MAX)
    c = c & (d0_ == jnp.min(d0_, axis=-1, keepdims=True))
    return jnp.argmax(jnp.where(c, d1, INT32_MIN), axis=-1).astype(jnp.int32)


def _prio_gt(sc, pl, i, j):
    """Heap-internal: element ``i`` strictly precedes element ``j`` under the
    total order, on whatever payload columns this heap carries."""
    W = pl.shape[1]
    z = jnp.int32(0)
    a0, b0 = (pl[i, 0], pl[j, 0]) if W >= 1 else (z, z)
    a1, b1 = (pl[i, 1], pl[j, 1]) if W >= 2 else (z, z)
    # payload col 1 is d1: *descending* in the order (see module docstring)
    return lex_gt(sc[i], a0, a1, sc[j], b0, b1)


class Heap(NamedTuple):
    scores: jnp.ndarray      # (cap,) float32
    payload: jnp.ndarray     # (cap, P) int32
    size: jnp.ndarray        # () int32
    overflowed: jnp.ndarray  # () bool

    @property
    def cap(self) -> int:
        return self.scores.shape[0]


def make(cap: int, payload_width: int) -> Heap:
    return Heap(
        scores=jnp.full((cap,), NEG_INF, dtype=jnp.float32),
        payload=jnp.zeros((cap, payload_width), dtype=jnp.int32),
        size=jnp.int32(0),
        overflowed=jnp.zeros((), dtype=bool),
    )


def push(h: Heap, score: jnp.ndarray, pay: jnp.ndarray,
         enable: jnp.ndarray | bool = True) -> Heap:
    """Insert (score, pay); no-op when ``enable`` is False or heap is full.

    A capacity-dropped enabled push latches ``overflowed``."""
    want = jnp.asarray(enable)
    enable = want & (h.size < h.cap)
    overflowed = h.overflowed | (want & (h.size >= h.cap))
    scores, payload, size, _ = h
    at = jnp.where(enable, size, jnp.int32(0))
    scores = scores.at[at].set(jnp.where(enable, score, scores[at]))
    payload = payload.at[at].set(jnp.where(enable, pay, payload[at]))

    def cond(st):
        i, sc, pl = st
        par = (i - 1) // 2
        return (i > 0) & _prio_gt(sc, pl, i, par)

    def body(st):
        i, sc, pl = st
        par = (i - 1) // 2
        si, sp = sc[i], sc[par]
        sc = sc.at[i].set(sp).at[par].set(si)
        pi, pp = pl[i], pl[par]
        pl = pl.at[i].set(pp).at[par].set(pi)
        return par, sc, pl

    i0 = jnp.where(enable, size, jnp.int32(0))
    _, scores, payload = jax.lax.while_loop(cond, body, (i0, scores, payload))
    return Heap(scores, payload, size + enable.astype(jnp.int32), overflowed)


def pop(h: Heap) -> tuple[jnp.ndarray, jnp.ndarray, Heap]:
    """Remove and return the max element.  Caller guards ``size > 0``."""
    scores, payload, size, overflowed = h
    top_s, top_p = scores[0], payload[0]
    last = jnp.maximum(size - 1, 0)
    scores = scores.at[0].set(scores[last]).at[last].set(NEG_INF)
    payload = payload.at[0].set(payload[last])
    size = last

    cap = h.cap

    def children(i, sc, pl):
        l, r = 2 * i + 1, 2 * i + 2
        # clamp the *index* (not the score) so lex gathers stay in bounds;
        # validity masks make the clamped reads inert
        lm, rm = jnp.minimum(l, cap - 1), jnp.minimum(r, cap - 1)
        return lm, rm, l < size, r < size

    def cond(st):
        i, sc, pl = st
        lm, rm, lv, rv = children(i, sc, pl)
        return ((lv & _prio_gt(sc, pl, lm, i))
                | (rv & _prio_gt(sc, pl, rm, i)))

    def body(st):
        i, sc, pl = st
        lm, rm, lv, rv = children(i, sc, pl)
        r_wins = rv & (~lv | _prio_gt(sc, pl, rm, lm))
        c = jnp.where(r_wins, rm, lm)
        si, scc = sc[i], sc[c]
        sc = sc.at[i].set(scc).at[c].set(si)
        pi, pc = pl[i], pl[c]
        pl = pl.at[i].set(pc).at[c].set(pi)
        return c, sc, pl

    _, scores, payload = jax.lax.while_loop(cond, body, (jnp.int32(0), scores, payload))
    return top_s, top_p, Heap(scores, payload, size, overflowed)


# ---------------------------------------------------------------------------
# frontier batching (beam search, DESIGN.md §6)
# ---------------------------------------------------------------------------

def pop_p(h: Heap, p: int) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Heap]:
    """Pop the ``p`` best elements (``p`` static).

    Returns ``(scores (p,), payloads (p, W), valid (p,), heap)``; pops past
    the current size are masked out (score -inf, valid False).  Pops come out
    in the total lex order — the same flattened sequence for every ``p``,
    which the beam emission rule and its schedule-invariance tests rely on.
    ``pop`` on an empty heap is already a structural no-op (the sift guard
    sees size 0), so no per-step branching is needed.
    """
    size0 = h.size

    def step(hp, _):
        s, pay, hp = pop(hp)
        return hp, (s, pay)

    h, (scores, payloads) = jax.lax.scan(step, h, None, length=p)
    valid = jnp.arange(p, dtype=jnp.int32) < size0
    return jnp.where(valid, scores, NEG_INF), payloads, valid, h


def push_many(h: Heap, scores: jnp.ndarray, pays: jnp.ndarray,
              enable: jnp.ndarray) -> Heap:
    """Bulk insert: ``scores (m,)``, ``pays (m, W)``, ``enable (m,)``.

    Sequential gated pushes in array order (the order is observable through
    pop tie-breaking, so beam callers keep it deterministic)."""

    def step(hp, x):
        s, pay, en = x
        return push(hp, s, pay, en), None

    h, _ = jax.lax.scan(step, h, (scores, pays, enable))
    return h


# ---------------------------------------------------------------------------
# bounded top-k result set (k is tiny: argmin replace beats a heap on VPU)
# ---------------------------------------------------------------------------

class TopK(NamedTuple):
    scores: jnp.ndarray  # (k,) float32, -inf padded
    docs: jnp.ndarray    # (k,) int32


def topk_make(k: int) -> TopK:
    return TopK(jnp.full((k,), NEG_INF, jnp.float32), jnp.full((k,), -1, jnp.int32))


def topk_insert(t: TopK, score: jnp.ndarray, doc: jnp.ndarray,
                enable: jnp.ndarray | bool = True) -> TopK:
    """Keep the k best pairs under the total order (score desc, doc asc).

    The retained *set* is insertion-order invariant, ties included: the
    replaced slot is the lex-least (min score, then max doc) and a candidate
    enters iff it lex-beats that slot — so a score tie at the boundary always
    resolves toward the lower doc id, matching the heap/`lax.top_k` order."""
    m = jnp.min(t.scores)
    at_min = t.scores == m
    worst = jnp.argmax(jnp.where(at_min, t.docs, INT32_MIN))
    better = jnp.asarray(enable) & (
        (score > m) | ((score == m) & (doc < t.docs[worst])))
    return TopK(
        scores=t.scores.at[worst].set(jnp.where(better, score, t.scores[worst])),
        docs=t.docs.at[worst].set(jnp.where(better, doc, t.docs[worst])),
    )


def topk_sorted(t: TopK) -> TopK:
    """Descending by score; ties by ascending doc id (deterministic output)."""
    order = jnp.lexsort((t.docs, -t.scores))
    return TopK(t.scores[order], t.docs[order])
