"""Relevance scoring: tf-idf (the paper's measure) and Okapi BM25 (extension).

The paper stores df_w per word ("insignificant extra space" by Heaps' law) and
computes ``tfidf(w, d) = tf_{w,d} * log(N / df_w)``, summing over query words.

WTBC-DR's prioritized traversal requires the score to be *monotone over
concatenation of documents* (score(d1 ++ d2) >= max(score(d1), score(d2))).
tf-idf with raw tf satisfies this; BM25 does not (document-length
normalization), which is exactly why the paper notes BM25 fits the DRB
strategy only.  ``assert_dr_compatible`` enforces that at the API level.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.wtbc import WTBCIndex


@dataclasses.dataclass(frozen=True)
class TfIdf:
    """score(d) = sum_w tf_{w,d} * ln(N / df_w)"""
    name: str = "tfidf"
    dr_compatible: bool = True

    def idf(self, idx: WTBCIndex) -> jnp.ndarray:
        df = jnp.maximum(idx.df.astype(jnp.float32), 1.0)
        return jnp.log(idx.n_docs.astype(jnp.float32) / df)

    def score(self, tf: jnp.ndarray, idf_w: jnp.ndarray,
              doc_len: jnp.ndarray | None = None,
              avg_dl: jnp.ndarray | None = None) -> jnp.ndarray:
        return jnp.sum(tf.astype(jnp.float32) * idf_w, axis=-1)


@dataclasses.dataclass(frozen=True)
class BM25:
    """Okapi BM25 (k1, b) — usable with WTBC-DRB (candidate-then-rank) only."""
    k1: float = 1.2
    b: float = 0.75
    name: str = "bm25"
    dr_compatible: bool = False

    def idf(self, idx: WTBCIndex) -> jnp.ndarray:
        df = idx.df.astype(jnp.float32)
        n = idx.n_docs.astype(jnp.float32)
        return jnp.log(1.0 + (n - df + 0.5) / (df + 0.5))

    def score(self, tf: jnp.ndarray, idf_w: jnp.ndarray,
              doc_len: jnp.ndarray | None = None,
              avg_dl: jnp.ndarray | None = None) -> jnp.ndarray:
        tf = tf.astype(jnp.float32)
        norm = 1.0 - self.b + self.b * (doc_len.astype(jnp.float32) / avg_dl)
        part = tf * (self.k1 + 1.0) / (tf + self.k1 * norm[..., None])
        return jnp.sum(part * idf_w, axis=-1)


def assert_dr_compatible(measure) -> None:
    if not measure.dr_compatible:
        raise ValueError(
            f"{measure.name} is not monotone over document concatenation; "
            "WTBC-DR's prioritized traversal requires tf-idf (paper §5). "
            "Use WTBC-DRB for BM25.")
