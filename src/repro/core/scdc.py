"""(s,c)-Dense Code — word-based byte-oriented semistatic statistical compressor.

The paper builds the WTBC on top of (s,c)-DC [Brisaboa et al., Inf.Retr. 2007]:
byte values ``[0, s)`` are *stoppers*, ``[s, 256)`` are *continuers* (``s+c = 256``).
A codeword is zero or more continuers terminated by exactly one stopper, so the
``s`` most frequent words get 1-byte codewords, the next ``s*c`` get 2 bytes, the
next ``s*c^2`` get 3 bytes, and so on.  ``(s, c)`` is chosen to minimize the
compressed size for the observed word-frequency distribution.

Everything here is host-side build logic (numpy); the query-time structures the
WTBC needs (codeword tables, per-word node paths) are emitted as plain arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

#: Maximum codeword length we materialize node-offset tables for.  With the
#: constraint enforced in :func:`optimal_sc`, every vocabulary we handle fits in
#: codewords of at most MAX_CODE_LEN bytes (the paper's 1GB corpus, 718,691
#: distinct words, fits in 3 bytes for every (s,c) with s*(1+c+c^2) >= |V|).
MAX_CODE_LEN = 3


def capacity(s: int, max_len: int = MAX_CODE_LEN) -> int:
    """Number of distinct codewords of length <= max_len for a given ``s``."""
    c = 256 - s
    total, width = 0, s
    for _ in range(max_len):
        total += width
        width *= c
    return total


def code_lengths(s: int, vocab_size: int, max_len: int = MAX_CODE_LEN) -> np.ndarray:
    """Length (in bytes) of the codeword of each frequency rank ``0..V-1``."""
    c = 256 - s
    lens = np.empty(vocab_size, dtype=np.int8)
    base, width = 0, s
    for k in range(1, max_len + 1):
        hi = min(vocab_size, base + width)
        lens[base:hi] = k
        base, width = base + width, width * c
        if base >= vocab_size:
            break
    if base < vocab_size:
        raise ValueError(
            f"vocab of {vocab_size} does not fit in {max_len}-byte (s={s},c={c}) codes"
        )
    return lens


def compressed_size(s: int, freqs_desc: np.ndarray, max_len: int = MAX_CODE_LEN) -> int:
    """Total compressed bytes when ranks are assigned by decreasing frequency."""
    lens = code_lengths(s, len(freqs_desc), max_len)
    return int(np.dot(lens.astype(np.int64), freqs_desc.astype(np.int64)))


def optimal_sc(freqs_desc: np.ndarray, max_len: int = MAX_CODE_LEN) -> tuple[int, int]:
    """Search ``s`` in [1, 255] minimizing compressed size (subject to fit).

    The classical (s,c)-DC optimization; the size function is unimodal-ish in
    ``s`` but cheap enough to scan exhaustively (255 evaluations).
    """
    best_s, best_sz = None, None
    v = len(freqs_desc)
    for s in range(1, 256):
        if capacity(s, max_len) < v:
            continue
        sz = compressed_size(s, freqs_desc, max_len)
        if best_sz is None or sz < best_sz:
            best_s, best_sz = s, sz
    if best_s is None:
        raise ValueError(f"no (s,c) fits a vocabulary of {v} words in {max_len} bytes")
    return best_s, 256 - best_s


def encode_table(s: int, vocab_size: int, max_len: int = MAX_CODE_LEN) -> tuple[np.ndarray, np.ndarray]:
    """Codewords for every rank: returns (codes (V, max_len) uint8, lens (V,) int8).

    Rank ``r``'s codeword is ``(k-1)`` continuers followed by one stopper, where
    ``k`` is the code length.  Within the k-byte band, writing
    ``x = r - base_k``:  stopper ``= x % s`` is the last byte and the continuer
    prefix is the base-c representation of ``x // s`` offset by ``s``.
    Vectorized over the whole vocabulary.
    """
    c = 256 - s
    lens = code_lengths(s, vocab_size, max_len)
    codes = np.zeros((vocab_size, max_len), dtype=np.uint8)
    r = np.arange(vocab_size, dtype=np.int64)
    base, width = 0, s
    for k in range(1, max_len + 1):
        sel = lens == k
        if not np.any(sel):
            base, width = base + width, width * c
            continue
        x = r[sel] - base
        codes[sel, k - 1] = (x % s).astype(np.uint8)          # stopper, last byte
        x = x // s
        for lvl in range(k - 2, -1, -1):                       # continuers, right to left
            codes[sel, lvl] = (s + (x % c)).astype(np.uint8)
            x = x // c
        base, width = base + width, width * c
    return codes, lens


def decode_rank(s: int, byteseq: Sequence[int]) -> int:
    """Inverse of :func:`encode_table` for one codeword (host-side scalar)."""
    c = 256 - s
    byteseq = [int(b) for b in byteseq]   # guard numpy uint8 overflow
    k = len(byteseq)
    x = 0
    for b in byteseq[:-1]:
        if not s <= b < 256:
            raise ValueError(f"byte {b} is not a continuer for s={s}")
        x = x * c + (b - s)
    last = byteseq[-1]
    if not 0 <= last < s:
        raise ValueError(f"terminal byte {last} is not a stopper for s={s}")
    x = x * s + int(last)
    base, width = 0, s
    for _ in range(1, k):
        base, width = base + width, width * c
    return base + x


@dataclasses.dataclass(frozen=True)
class SCDCModel:
    """A fitted (s,c)-DC model over a frequency-ranked vocabulary.

    ``rank_of_word`` / ``word_of_rank`` translate between original word ids and
    frequency ranks; codewords are assigned to *ranks*.
    """

    s: int
    c: int
    codes: np.ndarray          # (V, MAX_CODE_LEN) uint8, rank-indexed
    lens: np.ndarray           # (V,) int8, rank-indexed
    rank_of_word: np.ndarray   # (V,) int32: original word id -> frequency rank
    word_of_rank: np.ndarray   # (V,) int32: frequency rank   -> original word id
    freqs: np.ndarray          # (V,) int64, rank-indexed frequencies

    @property
    def vocab_size(self) -> int:
        return len(self.lens)

    def encode_tokens(self, tokens: np.ndarray) -> np.ndarray:
        """Compress a token-id sequence to a flat byte stream (for CR/CT benchmarks)."""
        ranks = self.rank_of_word[tokens]
        lens = self.lens[ranks].astype(np.int64)
        total = int(lens.sum())
        out = np.empty(total, dtype=np.uint8)
        ends = np.cumsum(lens)
        starts = ends - lens
        for k in range(1, MAX_CODE_LEN + 1):
            sel = lens >= k
            out[starts[sel] + (k - 1)] = self.codes[ranks[sel], k - 1]
        return out

    def decode_bytes(self, stream: np.ndarray) -> np.ndarray:
        """Decompress a byte stream back to token ids (vectorized)."""
        stream = np.asarray(stream, dtype=np.uint8)
        is_stop = stream < self.s
        ends = np.flatnonzero(is_stop)
        starts = np.concatenate(([0], ends[:-1] + 1))
        lens = ends - starts + 1
        x = np.zeros(len(ends), dtype=np.int64)
        maxlen = int(lens.max()) if len(lens) else 0
        for off in range(maxlen - 1):                    # accumulate continuers
            sel = lens > off + 1
            x[sel] = x[sel] * self.c + (stream[starts[sel] + off].astype(np.int64) - self.s)
        x = x * self.s + stream[ends].astype(np.int64)
        base, width = 0, self.s
        bases = np.zeros(maxlen + 1, dtype=np.int64)
        for k in range(1, maxlen + 1):
            bases[k] = base
            base, width = base + width, width * self.c
        ranks = bases[lens] + x
        return self.word_of_rank[ranks]


def fit(freqs_by_word: np.ndarray, reserve_first: int | None = 0,
        max_len: int = MAX_CODE_LEN) -> SCDCModel:
    """Fit (s,c)-DC to per-word frequencies.

    ``reserve_first``: word id that must receive frequency rank 0 (the paper
    reserves the first 1-byte codeword for the document separator ``'$'`` so it
    can be found directly in the WTBC root).  Pass ``None`` to disable.
    """
    freqs_by_word = np.asarray(freqs_by_word, dtype=np.int64)
    order = np.argsort(-freqs_by_word, kind="stable").astype(np.int32)
    if reserve_first is not None:
        pos = int(np.flatnonzero(order == reserve_first)[0])
        order = np.concatenate(([reserve_first], np.delete(order, pos))).astype(np.int32)
    rank_of_word = np.empty_like(order)
    rank_of_word[order] = np.arange(len(order), dtype=np.int32)
    freqs_desc = freqs_by_word[order]
    s, c = optimal_sc(freqs_desc, max_len)
    codes, lens = encode_table(s, len(order), max_len)
    return SCDCModel(s=s, c=c, codes=codes, lens=lens,
                     rank_of_word=rank_of_word, word_of_rank=order,
                     freqs=freqs_desc)
