"""WTBC-DRB: ranked retrieval with additional per-word tf bitmaps (paper §3.2).

For every word whose idf exceeds a threshold eps, a bitmap
``1 0^{tf1-1} 1 0^{tf2-1} ...`` encodes its document list and per-document
term frequencies (one bit per *occurrence*; a 1 marks the first occurrence in
a new document).  All bitmaps live concatenated in one packed ``BitVec`` with
a per-word offset table.

Conjunctive queries: candidate generation walks the word with the fewest
unprocessed documents (the paper's triplets ``(wID, nDocs, i)``), locates the
candidate document through the WTBC, verifies/counts the remaining words with
count-range inside the document extent, and skips all cursors past the
candidate.  Bag-of-words: every word's documents are enumerated from its
bitmap and aggregated (here: a vectorized gather/scatter over a doc-score
table + one top-k, the TPU-shaped equivalent of the paper's sort-merge).

Because DRB scores fully materialized candidates, any additive-per-word
measure works — tf-idf (paper) and BM25 (paper §5's noted extension).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvec, heap as H, wtbc
from repro.core.bitvec import BitVec
from repro.core.ranked import DRResult
from repro.core.wtbc import WTBCIndex

INT32_MAX = jnp.int32(2**31 - 1)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("bv", "bit_off", "has_bm"), meta_fields=("eps",))
@dataclasses.dataclass(frozen=True)
class DRBAux:
    """The paper's 'small additional bitmaps' (its measured overhead: +3%)."""
    bv: BitVec            # concatenated tf bitmaps, word-rank order
    bit_off: jnp.ndarray  # (V+1,) int32
    has_bm: jnp.ndarray   # (V,) bool — idf >= eps (stopwords filtered out)
    eps: float


def build_aux(idx: WTBCIndex, model, doc_tokens: list[np.ndarray],
              eps: float = 1e-6,
              has_bm_override: np.ndarray | None = None) -> DRBAux:
    """Host-side bitmap construction.

    eps follows the paper (1e-6 leaves out only near-universal stopwords).
    ``has_bm_override``: sharded builds pass the *global* stopword decision so
    every shard stores bitmaps for the same word set.
    """
    V = model.vocab_size
    n_docs = len(doc_tokens)
    if has_bm_override is not None:
        has_bm = np.asarray(has_bm_override).copy()
    else:
        df = np.asarray(idx.df)
        idf = np.log(np.maximum(n_docs, 1) / np.maximum(df, 1))
        has_bm = (idf >= eps) & (df > 0)
    has_bm[wtbc.SEP_RANK] = False

    # occurrences of stored words as (word_rank, doc) pairs, sorted
    ranks_list, docs_list = [], []
    for d, toks in enumerate(doc_tokens):
        r = model.rank_of_word[toks]
        keep = has_bm[r]
        ranks_list.append(r[keep].astype(np.int64))
        docs_list.append(np.full(int(keep.sum()), d, dtype=np.int64))
    ranks = np.concatenate(ranks_list) if ranks_list else np.zeros(0, np.int64)
    docs = np.concatenate(docs_list) if docs_list else np.zeros(0, np.int64)
    order = np.lexsort((docs, ranks))
    ranks, docs = ranks[order], docs[order]

    occ_stored = np.bincount(ranks, minlength=V)
    bit_off = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(occ_stored, out=bit_off[1:])
    n_bits = int(bit_off[-1])

    # a bit position is 1 iff its (word, doc) differs from its predecessor's
    pair = ranks * n_docs + docs
    is_one = np.ones(len(pair), dtype=bool)
    is_one[1:] = pair[1:] != pair[:-1]
    set_bits = np.flatnonzero(is_one)
    bv = bitvec.build(set_bits, max(n_bits, 1))
    return DRBAux(
        bv=bv,
        bit_off=jnp.asarray(bit_off.astype(np.int32)),
        has_bm=jnp.asarray(has_bm),
        eps=eps,
    )


def space_report(aux: DRBAux) -> dict[str, int]:
    return {
        "bitmap_bits_bytes": int(np.asarray(aux.bv.words).nbytes),
        "bitmap_counters": int(np.asarray(aux.bv.counts).nbytes),
        "bit_offsets": int(np.asarray(aux.bit_off).nbytes),
    }


# word-relative bitmap ops ----------------------------------------------------

def word_rank1(aux: DRBAux, w: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    """ones among the first i bits of word w's bitmap (= docs fully passed)."""
    off = aux.bit_off[w]
    return bitvec.rank1(aux.bv, off + i) - bitvec.rank1(aux.bv, off)


def word_select1(aux: DRBAux, w: jnp.ndarray, j: jnp.ndarray) -> jnp.ndarray:
    """bit position (word-relative) of the j-th 1 in w's bitmap."""
    off = aux.bit_off[w]
    base = bitvec.rank1(aux.bv, off)
    return bitvec.select1(aux.bv, base + j) - off


def word_occ(aux: DRBAux, w: jnp.ndarray) -> jnp.ndarray:
    return aux.bit_off[w + 1] - aux.bit_off[w]


# ---------------------------------------------------------------------------
# conjunctive (AND) — the paper's triplet walk
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "measure", "beam_width",
                                             "max_pops"))
def topk_drb_and(idx: WTBCIndex, aux: DRBAux, words: jnp.ndarray,
                 wmask: jnp.ndarray, measure, *, k: int,
                 idf: jnp.ndarray | None = None,
                 avg_dl: jnp.ndarray | None = None,
                 beam_width: int = 1,
                 max_pops: int | None = None) -> DRResult:
    """Paper §3.2 conjunctive search.  O(df_min) candidate iterations; each
    iteration verifies ``beam_width`` (= P) candidate documents of the rarest
    word at once — P locates, then one fused batched descent for all P×Q
    in-document counts plus the Q cursor-advance prefix counts (DESIGN.md §6).

    ``idf``/``avg_dl`` default to this index's own statistics; distributed
    callers pass the *global* tables so shard scores are comparable.

    Word semantics: a masked word with no bitmap because it is a *stopword*
    (idf < eps) is excluded from the conjunction and from scoring (paper
    footnote 1); a masked word **absent from the collection** (df = 0) makes
    the conjunction empty.

    Beam exactness is trivial here (unlike DR): the walk enumerates and fully
    verifies every candidate regardless of P — P only changes how many are
    in flight per loop trip; consecutive occurrences landing in one document
    are deduplicated before the bounded top-k insert.  The insert keeps the
    total order (score desc, doc asc), so the retained set — score ties at
    the k boundary included — is independent of P and of candidate arrival
    order.  ``beam_width=1`` is step-for-step the paper's triplet walk.

    ``max_pops`` is the anytime budget in *candidate documents examined*
    (the ``pops`` work leaf).  Unlike DR, the walk visits candidates in
    document order, not score order, so certification is all-or-nothing
    (DESIGN.md §11): a completed walk is exact (every slot certified,
    ``bound`` -inf); a budget-stopped walk has examined an arbitrary score
    mix (no slot certified, ``bound`` +inf — an unexamined candidate may
    score anything).
    """
    Q = words.shape[0]
    P = int(beam_width)
    valid = wmask & aux.has_bm[words]
    idf_all = measure.idf(idx) if idf is None else idf
    idf_w = jnp.where(valid, idf_all[words], 0.0).astype(jnp.float32)
    df_w = idx.df[words]
    if avg_dl is None:
        # sum/n_docs (not mean) — doc_len may be zero-padded in sharded stacks
        avg_dl = jnp.sum(idx.doc_len.astype(jnp.float32)) / idx.n_docs.astype(jnp.float32)
    absent = jnp.any(wmask & (df_w == 0))

    # state: per-word occurrence cursor p (0-based, sits on a 1-bit), docs
    # left, candidate-documents-examined counter (the pops work metric)
    p0 = jnp.zeros((Q,), jnp.int32)
    nd0 = jnp.where(valid, df_w, INT32_MAX)
    topk0 = H.topk_make(k)

    def has_work(nd):
        return (jnp.min(nd) > 0) & jnp.any(valid) & ~absent

    def cond(st):
        p, nd, topk, it, cands, padded = st
        ok = has_work(nd) & (it < idx.n_docs + 1)
        if max_pops is not None:
            ok = ok & (cands < max_pops)
        return ok

    def body(st):
        p, nd, topk, it, cands, padded = st
        qstar = jnp.argmin(jnp.where(valid, nd, INT32_MAX))
        wstar = words[qstar]
        occ_star = idx.occ[wstar]
        # candidates: the next P occurrences of the rarest word (their
        # documents are non-decreasing; the first is always a fresh one
        # because cursors sit on document boundaries)
        js = p[qstar] + 1 + jnp.arange(P, dtype=jnp.int32)
        valid_j = js <= occ_star
        pos_j = jax.vmap(lambda j: wtbc.locate(
            idx, wstar, jnp.minimum(j, jnp.maximum(occ_star, 1))))(js)
        d_j = jax.vmap(lambda pp: wtbc.doc_of_pos(idx, pp))(pos_j)
        new_j = valid_j & (d_j != jnp.concatenate(
            [jnp.full((1,), -1, jnp.int32), d_j[:-1]]))
        lo_j, hi_j = wtbc.segment_extent(idx, d_j, d_j + 1)
        d_last = jnp.max(jnp.where(valid_j, d_j, -1))
        hi_last = wtbc.segment_extent(idx, d_last, d_last + 1)[1]
        # one fused batch: P×Q in-document tfs + Q prefix counts at the last
        # candidate's end (the cursor-skip counts).  At P=1 this is the same
        # 2Q rank-descent workload as the classical walk.
        cnt = wtbc.count_range_batch(
            idx,
            jnp.concatenate([jnp.tile(words, P), words]),
            jnp.concatenate([jnp.repeat(lo_j, Q), jnp.zeros((Q,), jnp.int32)]),
            jnp.concatenate([jnp.repeat(hi_j, Q),
                             jnp.broadcast_to(hi_last, (Q,))]))
        tf = cnt[:P * Q].reshape(P, Q) * valid                     # (P, Q)
        cnt_last = cnt[P * Q:]
        present = new_j & jnp.all((tf > 0) | ~valid, axis=-1) & jnp.any(valid)
        score = measure.score(tf, idf_w, idx.doc_len[d_j], avg_dl)  # (P,)

        def ins(tk, x):
            s_, d_, en_ = x
            return H.topk_insert(tk, s_, d_, en_), None

        topk, _ = jax.lax.scan(ins, topk, (score, d_j, present))
        # advance all cursors past the last candidate (paper: recompute
        # triplets)
        p_new = jnp.where(valid, cnt_last, p)
        nd_new = jax.vmap(lambda w_, c_: word_rank1(aux, w_, c_))(words, cnt_last)
        nd_new = jnp.where(valid, df_w - nd_new, INT32_MAX)
        # pad-waste: beam lanes past the rarest word's posting-list end
        # still paid their locate + descent (SearchResults.diagnostics)
        return (p_new, nd_new, topk, it + 1,
                cands + jnp.sum(new_j.astype(jnp.int32)),
                padded + jnp.sum((~valid_j).astype(jnp.int32)))

    p, nd, topk, iters, cands, padded = jax.lax.while_loop(
        cond, body, (p0, nd0, topk0, jnp.int32(0), jnp.int32(0),
                     jnp.int32(0)))
    res = H.topk_sorted(topk)
    found = jnp.sum(res.scores > -jnp.inf).astype(jnp.int32)
    complete = ~has_work(nd)   # stopped because done, not because budgeted
    return DRResult(jnp.where(res.scores > -jnp.inf, res.docs, -1),
                    res.scores, found, iters, cands, jnp.zeros((), bool),
                    padded,
                    certified=(res.scores > -jnp.inf) & complete,
                    bound=jnp.where(complete, H.NEG_INF, jnp.float32(jnp.inf)))


# ---------------------------------------------------------------------------
# bag-of-words (OR) — enumerate every word's documents from its bitmap
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "measure", "max_df_cap"))
def topk_drb_or(idx: WTBCIndex, aux: DRBAux, words: jnp.ndarray,
                wmask: jnp.ndarray, measure, *, k: int, max_df_cap: int,
                idf: jnp.ndarray | None = None,
                avg_dl: jnp.ndarray | None = None) -> DRResult:
    """Paper §3.2 bag-of-words: per word, walk its 1-bits (document starts),
    locate each document's first occurrence through the WTBC, read tf as the
    gap to the next 1, aggregate per document, take the top-k.

    TPU adaptation: the per-word walk is a padded (Q, max_df_cap) gather and
    the aggregation is one scatter-add into a document-score table + one
    ``lax.top_k`` — replacing the paper's sort-merge with dense vector ops.
    ``max_df_cap`` must be >= max document frequency among the query words.
    """
    Q = words.shape[0]
    n_docs_static = idx.sep_pos.shape[0]
    valid = wmask & aux.has_bm[words]
    idf_all = measure.idf(idx) if idf is None else idf
    idf_w = jnp.where(valid, idf_all[words], 0.0).astype(jnp.float32)
    df_w = jnp.where(valid, idx.df[words], 0)
    occ_w = jax.vmap(lambda w_: word_occ(aux, w_))(words)
    if avg_dl is None:
        avg_dl = jnp.sum(idx.doc_len.astype(jnp.float32)) / idx.n_docs.astype(jnp.float32)

    js = jnp.arange(max_df_cap, dtype=jnp.int32)

    def per_word(q):
        w = words[q]
        live = (js < df_w[q]) & valid[q]
        # one select1 per document: hoist the word's bitmap base rank (was
        # recomputed per j) and diff consecutive selects instead of running a
        # second select pass for the next-1 positions (§Perf hillclimb 3:
        # 6 counter-block ops per doc -> 1).
        off = aux.bit_off[w]
        base = bitvec.rank1(aux.bv, off)
        sels = jax.vmap(
            lambda j: bitvec.select1(aux.bv, base + j + 1) - off
        )(jnp.arange(max_df_cap + 1, dtype=jnp.int32))                     # (cap+1,)
        sel = sels[:-1]                                                    # i_j
        tf = jnp.where(js + 1 < df_w[q], sels[1:], occ_w[q]) - sel
        first_occ = jax.vmap(lambda i: wtbc.locate(idx, w, i + 1))(sel)
        d = jax.vmap(lambda pp: wtbc.doc_of_pos(idx, pp))(first_occ)
        d = jnp.where(live, d, n_docs_static)                              # OOB drop
        return d, jnp.where(live, tf, 0)

    docs_m, tf_m = jax.vmap(per_word)(jnp.arange(Q))                       # (Q, cap)

    # per-(word, doc) tf table -> additive measures need tf before transform
    tf_table = jnp.zeros((Q, n_docs_static + 1), jnp.int32)
    tf_table = tf_table.at[jnp.arange(Q)[:, None], docs_m].add(tf_m)
    tf_table = tf_table[:, :n_docs_static]                                 # (Q, N)
    scores = measure.score(tf_table.T, idf_w, idx.doc_len, avg_dl)         # (N,)
    scores = jnp.where(jnp.any(tf_table.T * valid > 0, axis=-1), scores, -jnp.inf)

    top_s, top_d = jax.lax.top_k(scores, k)
    found = jnp.sum(top_s > -jnp.inf).astype(jnp.int32)
    # loop-free dense pass: always exhaustive, hence always fully certified
    return DRResult(jnp.where(top_s > -jnp.inf, top_d, -1).astype(jnp.int32),
                    top_s.astype(jnp.float32), found, jnp.int32(max_df_cap),
                    jnp.int32(max_df_cap), jnp.zeros((), bool),
                    certified=top_s > -jnp.inf, bound=H.NEG_INF)
