"""Megabatch DR core: per-row best-first frontiers in dense pools (no heap).

``topk_dr_batch`` batches Algorithm 1 by vmapping the serial heap core — but
a binary-heap sift is a *data-dependent* sequence of two-element swaps, and
under ``vmap`` each swap lowers to a whole-buffer XLA scatter across the
batch (measured ~6x slower than running the rows serially).  This module is
the batched core the serving path actually wants: the frontier of every row
lives in an **unsorted** ``(B, cap)`` pool and the heap operations become
dense row-parallel primitives —

  extract-max   three masked reductions (``heap.lex_argmax``): max score,
                min d0 among score ties, max d1 — the total lex order
                ``(score desc, d0 asc, d1 desc)`` shared with the heap;
  insert        first-free-slot scatter (``argmax`` over the free mask);
                slot position is irrelevant because extraction never looks
                at order, only at keys.

Each loop trip pops exactly one segment per live row (classical
``beam_width=1`` semantics per row — the batch dim *is* the parallelism),
splits multi-document segments with ONE fused ``count_range_batch`` over all
B×Q left-child counts, and re-inserts the children.  Because pops follow the
same total lex order as the heap, every row's pop/emission sequence is
**bitwise identical** to its own serial ``topk_dr`` run at the same Q bucket
(tests/test_mega.py pins this across ≥200 seeded cases); the known caveat is
cross-Q-bucket BM25-style 1-ulp drift from shape-dependent FMA, which does
not apply here (DR scores reduce over the same Q lanes on both paths).

A pool of ``cap >= n_docs + 2`` can never overflow: the frontier of the
document-range split tree holds at most ``n_docs`` segments (every split
removes one node and adds at most two, and there are at most ``n_docs - 1``
splits).  Smaller caps drop the insert and latch ``overflowed`` per row,
mirroring the heap's contract (DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import heap as H
from repro.core import wtbc
from repro.core.ranked import DRResult
from repro.core.wtbc import WTBCIndex


def _pool_insert(pool, s, d0, d1, tf, enable, overflowed):
    """Insert one segment per row into the first free slot (score -inf marks
    free).  A full pool drops the enabled insert and latches ``overflowed``."""
    pool_s, pool_d0, pool_d1, pool_tf = pool
    B = pool_s.shape[0]
    free = pool_s == H.NEG_INF
    has_free = jnp.any(free, axis=1)
    slot = jnp.argmax(free, axis=1).astype(jnp.int32)
    ok = enable & has_free
    overflowed = overflowed | (enable & ~has_free)
    row = jnp.arange(B, dtype=jnp.int32)
    pool_s = pool_s.at[row, slot].set(
        jnp.where(ok, s, pool_s[row, slot]))
    pool_d0 = pool_d0.at[row, slot].set(
        jnp.where(ok, d0, pool_d0[row, slot]))
    pool_d1 = pool_d1.at[row, slot].set(
        jnp.where(ok, d1, pool_d1[row, slot]))
    pool_tf = pool_tf.at[row, slot].set(
        jnp.where(ok[:, None], tf, pool_tf[row, slot]))
    return (pool_s, pool_d0, pool_d1, pool_tf), overflowed


def _mega_finalize(pool, out_docs, out_scores, n_out, overflowed, *, k: int,
                   harvest: bool):
    """Row-parallel anytime epilogue — the dense-pool analogue of
    ``ranked._anytime_finalize`` (same harvest order, same pending bound,
    same certification rule; DESIGN.md §11).  Because the pool holds exactly
    the segments the serial heap would hold at the same pop count, every
    leaf this produces is bitwise equal to the serial core's row-for-row.
    """
    pool_s, pool_d0, pool_d1, _ = pool
    B = pool_s.shape[0]
    row = jnp.arange(B, dtype=jnp.int32)
    valid = pool_s > H.NEG_INF
    single = valid & ((pool_d1 - pool_d0) == 1)
    remaining = valid

    if harvest:
        def step(_, st):
            out_docs, out_scores, n_out, sing = st
            j = H.lex_argmax(pool_s, pool_d0, pool_d1, sing)
            write = jnp.any(sing, axis=1) & (n_out < k)
            at = jnp.where(write, n_out, k)
            out_docs = out_docs.at[row, at].set(
                jnp.where(write, pool_d0[row, j], out_docs[row, at]))
            out_scores = out_scores.at[row, at].set(
                jnp.where(write, pool_s[row, j], out_scores[row, at]))
            sing = sing.at[row, j].set(sing[row, j] & ~write)
            return (out_docs, out_scores, n_out + write.astype(jnp.int32),
                    sing)

        out_docs, out_scores, n_out, left = jax.lax.fori_loop(
            0, k, step, (out_docs, out_scores, n_out, single))
        remaining = (valid & ~single) | left

    has_rem = jnp.any(remaining, axis=1)
    j = H.lex_argmax(pool_s, pool_d0, pool_d1, remaining)
    bnd_s = jnp.where(has_rem, pool_s[row, j], H.NEG_INF)
    bnd_d0 = jnp.where(has_rem, pool_d0[row, j], H.INT32_MAX)
    bnd_d1 = jnp.where(has_rem, pool_d1[row, j], H.INT32_MIN)
    filled = (jnp.arange(out_docs.shape[1], dtype=jnp.int32)[None, :]
              < n_out[:, None])
    certified = filled & ~overflowed[:, None] & H.lex_gt(
        out_scores, out_docs, out_docs + 1,
        bnd_s[:, None], bnd_d0[:, None], bnd_d1[:, None])
    return out_docs, out_scores, n_out, certified[:, :k], bnd_s


@functools.partial(jax.jit,
                   static_argnames=("k", "conjunctive", "cap", "max_pops",
                                    "fused"))
def topk_dr_mega(idx: WTBCIndex, words: jnp.ndarray, wmask: jnp.ndarray,
                 idf: jnp.ndarray, *, k: int, conjunctive: bool,
                 cap: int, max_pops: int | None = None,
                 fused: str | None = None) -> DRResult:
    """Pool-frontier Algorithm 1 over a whole batch: ``words``/``wmask`` are
    (B, Q); returns a ``DRResult`` with (B,) / (B, k) leaves, row-for-row
    bitwise equal to ``topk_dr_batch(..., beam_width=1)`` at the same shapes
    (same docs, scores, n_found, iters, pops).

    ``max_pops`` is the per-row any-time budget; rows stop independently, so
    a straggler row never holds finished rows' results hostage — only the
    loop trip count, which is the max over rows either way.

    ``fused`` selects the device-resident loop body: ``None`` runs the jnp
    body below; ``"gpu"`` / ``"gpu:interpret"`` replace the whole trip —
    pop, descent, score, push — with ONE ``kernels/beam_step`` launch
    (bitwise-equal by construction and pinned by tests/test_beam_fused.py).
    Resolve the plan OUTSIDE jit (``backend.descent_plan().tag``).
    """
    B, Q = words.shape
    idf_w = jnp.where(wmask, idf[words], 0.0).astype(jnp.float32)

    def seg_score(tf):
        # (B, Q) int32 -> (B,) float32.  einsum('bq,bq->b') lowers to the
        # same per-row sequential dot as the serial core's (Q,)@(Q,) —
        # bitwise equality with per-row execution depends on this form
        # (jnp.sum(tf * idf, -1) does NOT reduce in the same order).
        return jnp.einsum("bq,bq->b", tf.astype(jnp.float32), idf_w)

    def seg_valid(tf, score):
        if conjunctive:
            return (jnp.all((tf > 0) | ~wmask, axis=-1)
                    & jnp.any(wmask, axis=-1))
        return score > 0.0

    n_docs = idx.n_docs
    lo0, hi0 = wtbc.segment_extent(idx, jnp.int32(0), n_docs)
    tf0 = wtbc.count_range_batch(
        idx, words.reshape(B * Q), jnp.broadcast_to(lo0, (B * Q,)),
        jnp.broadcast_to(hi0, (B * Q,))).reshape(B, Q) * wmask
    score0 = seg_score(tf0)

    pool = (jnp.full((B, cap), H.NEG_INF, jnp.float32),
            jnp.zeros((B, cap), jnp.int32),
            jnp.zeros((B, cap), jnp.int32),
            jnp.zeros((B, cap, Q), jnp.int32))
    overflowed0 = jnp.zeros((B,), bool)
    pool, overflowed0 = _pool_insert(
        pool, score0, jnp.zeros((B,), jnp.int32),
        jnp.broadcast_to(n_docs, (B,)).astype(jnp.int32), tf0,
        seg_valid(tf0, score0), overflowed0)

    # emission slots (k + 1 trash slot), same layout as the serial core
    out_docs = jnp.full((B, k + 1), -1, jnp.int32)
    out_scores = jnp.full((B, k + 1), -jnp.inf, jnp.float32)
    row = jnp.arange(B, dtype=jnp.int32)

    def live(pool, n_out, pops):
        ok = (n_out < k) & jnp.any(pool[0] > H.NEG_INF, axis=1)
        if max_pops is not None:
            ok = ok & (pops < max_pops)
        return ok

    def cond(st):
        pool, _, _, n_out, _, pops, _ = st
        return jnp.any(live(pool, n_out, pops))

    def body(st):
        pool, out_docs, out_scores, n_out, iters, pops, overflowed = st
        pool_s, pool_d0, pool_d1, pool_tf = pool
        active = live(pool, n_out, pops)

        # extract-max: dense lex-argmax per row, then clear the slot
        j = H.lex_argmax(pool_s, pool_d0, pool_d1, pool_s > H.NEG_INF)
        s_p = pool_s[row, j]
        d0, d1 = pool_d0[row, j], pool_d1[row, j]
        tf = pool_tf[row, j]
        pool_s = pool_s.at[row, j].set(
            jnp.where(active, H.NEG_INF, pool_s[row, j]))

        # one pop per row per trip => a popped singleton is the lex-greatest
        # pending segment of its row, hence always the next answer (the
        # P=1 emission rule of the serial core, row-parallel)
        single = active & ((d1 - d0) == 1)
        multi = active & ~single
        slot = jnp.where(single & (n_out < k), n_out, k)
        out_docs = out_docs.at[row, slot].set(
            jnp.where(single, d0, out_docs[row, slot]))
        out_scores = out_scores.at[row, slot].set(
            jnp.where(single, s_p, out_scores[row, slot]))
        n_out = jnp.minimum(n_out + single.astype(jnp.int32), k)

        # split every popped multi; all B×Q left-child tfs in ONE fused
        # batched descent (masked rows compute degenerate extents and are
        # discarded by the insert enables)
        mid = (d0 + d1) // 2
        lo1, hi1 = wtbc.segment_extent(idx, d0, mid)
        tf1 = wtbc.count_range_batch(
            idx, words.reshape(B * Q), jnp.repeat(lo1, Q),
            jnp.repeat(hi1, Q)).reshape(B, Q) * wmask
        tf2 = tf - tf1
        s1, s2 = seg_score(tf1), seg_score(tf2)
        pool = (pool_s, pool_d0, pool_d1, pool_tf)
        pool, overflowed = _pool_insert(
            pool, s1, d0, mid, tf1, multi & seg_valid(tf1, s1), overflowed)
        pool, overflowed = _pool_insert(
            pool, s2, mid, d1, tf2, multi & seg_valid(tf2, s2), overflowed)
        return (pool, out_docs, out_scores, n_out,
                iters + active.astype(jnp.int32),
                pops + active.astype(jnp.int32), overflowed)

    if fused is not None:
        if not fused.startswith("gpu"):
            raise ValueError(f"fused beam step has a gpu/interpret lowering "
                             f"only, got {fused!r}")
        from repro.kernels import beam_step

        def body(st):  # noqa: F811 — the fused replacement of the jnp trip
            pool, out_docs, out_scores, n_out, iters, pops, overflowed = st
            return beam_step.fused_beam_step(
                idx, words, wmask, idf_w, pool, out_docs, out_scores,
                n_out, iters, pops, overflowed, k=k, conjunctive=conjunctive,
                cap=cap, max_pops=max_pops,
                interpret=fused.endswith(":interpret"))

    st0 = (pool, out_docs, out_scores, jnp.zeros((B,), jnp.int32),
           jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
           overflowed0)
    (pool, out_docs, out_scores, n_out, iters, pops,
     overflowed) = jax.lax.while_loop(cond, body, st0)
    out_docs, out_scores, n_out, certified, bound = _mega_finalize(
        pool, out_docs, out_scores, n_out, overflowed, k=k,
        harvest=max_pops is not None)
    return DRResult(out_docs[:, :k], out_scores[:, :k], n_out, iters, pops,
                    overflowed, certified=certified, bound=bound)
