"""Serving launcher — a thin CLI over the ``repro.serve`` subsystem.

Starts a :class:`repro.serve.SearchServer` from a **snapshot** when one
exists (the paper's premise: the compressed index is the only thing we
keep), else builds from a synthetic corpus (optionally persisting the
snapshot for next boot), prints the index space report, precompiles every
executor bucket, then drives load and reports latency percentiles:

  # build once, snapshot, serve 2000 closed-loop requests
  PYTHONPATH=src python -m repro.launch.serve --docs 2000 \
      --snapshot-dir /tmp/wtbc-snap --save-snapshot --requests 2000

  # next boot: no corpus, no build — straight from the snapshot
  PYTHONPATH=src python -m repro.launch.serve --snapshot-dir /tmp/wtbc-snap \
      --target-qps 200 --requests 500 --mode or --strategy drb --measure bm25

``--target-qps 0`` (default) runs the closed-loop shape (``--workers``
back-to-back clients); a positive value runs the open-loop Poisson shape.
``--smoke`` exits non-zero unless the run was healthy (finite p99, zero
shed) — the CI serving smoke job drives exactly this.

Deadlines & SLA classes (DESIGN.md §11): ``--deadline-ms`` asks for
anytime answers — admission converts the wall target into a pop budget at
the live us/pop estimate and every response carries per-slot certified
bits.  ``--sla best_effort`` additionally lets overload shrink budgets
(degraded serving) before shedding; ``--retries N`` adds client-side
jittered-backoff retries on shed.  The CI ``anytime-smoke`` job drives
these flags end to end.

Observability (DESIGN.md §10): ``--metrics`` enables the process
:mod:`repro.obs` registry (span timelines, per-stage histograms, live
roofline gauges); ``--metrics-port N`` additionally serves Prometheus text
at ``http://127.0.0.1:N/metrics`` (0 = ephemeral, the chosen port is
printed) plus a JSON snapshot at ``/metrics.json``; ``--stats-every S``
appends one JSONL registry snapshot every S seconds to ``--stats-jsonl``
(or stdout).  Any of the three implies ``--metrics``.
"""
from __future__ import annotations

import argparse
import sys
import threading

import numpy as np

import repro.obs as obs
from repro.engine import SearchEngine
from repro.engine.facade import MEASURES
from repro.serve import QueryProfile, SearchServer, loadgen, snapshot
from repro.text import corpus


def build_or_load(args) -> SearchEngine:
    if args.snapshot_dir and snapshot.list_versions(args.snapshot_dir):
        v = snapshot.list_versions(args.snapshot_dir)[-1]
        print(f"loading snapshot v{v} from {args.snapshot_dir} ...", flush=True)
        return snapshot.load(args.snapshot_dir)
    print(f"building corpus: {args.docs} docs ...", flush=True)
    cp = corpus.make_corpus(args.docs, args.mean_doc_len, args.vocab,
                            seed=args.seed)
    if args.shards:
        engine = SearchEngine.shard(cp, n_shards=args.shards)
    else:
        engine = SearchEngine.build(cp)
    if args.save_snapshot:
        if not args.snapshot_dir:
            raise SystemExit("--save-snapshot needs --snapshot-dir")
        p = snapshot.save(engine, args.snapshot_dir)
        print(f"snapshot committed: {p}")
    return engine


def print_space_report(engine: SearchEngine) -> None:
    rep = engine.space_report()
    text = rep["level_bytes"]
    print("index space (bytes):")
    for k, v in rep.items():
        if k != "total":
            print(f"  {k:20s} {v:12,d}  ({v / max(text, 1):6.1%} of "
                  "compressed text)")
    print(f"  {'total':20s} {rep['total']:12,d}")


def main():
    ap = argparse.ArgumentParser()
    # corpus/build (ignored when a snapshot is loaded)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--mean-doc-len", type=int, default=300)
    ap.add_argument("--vocab", type=int, default=20000)
    ap.add_argument("--shards", type=int, default=0,
                    help="0 = single index; N = document-sharded local mesh")
    ap.add_argument("--seed", type=int, default=0)
    # snapshot
    ap.add_argument("--snapshot-dir", default=None,
                    help="load the newest snapshot here (skips the build); "
                         "with --save-snapshot, also where builds are saved")
    ap.add_argument("--save-snapshot", action="store_true")
    # query profile
    ap.add_argument("--mode", default="or",
                    choices=("and", "or", "phrase", "near"))
    ap.add_argument("--strategy", default="auto", choices=("dr", "drb", "auto"))
    ap.add_argument("--measure", default="tfidf", choices=("tfidf", "bm25"))
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--words", type=int, default=3, help="words per query")
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall target: admission converts it to "
                         "a pop budget at the live us/pop estimate "
                         "(DESIGN.md §11); answers carry certified bits")
    ap.add_argument("--sla", default=None,
                    choices=("exact", "bounded", "best_effort"),
                    help="SLA class (default: engine config; auto-'bounded' "
                         "when --budget/--deadline-ms is given).  'exact' "
                         "rejects anytime knobs; 'best_effort' additionally "
                         "lets overload shrink budgets before shedding")
    ap.add_argument("--retries", type=int, default=0,
                    help="client-side retry budget on shed (jittered "
                         "exponential backoff; the report prints the "
                         "attempts histogram)")
    ap.add_argument("--beam-width", type=int, default=None)
    ap.add_argument("--mega", action="store_true",
                    help="route DR and/or batches through the pool-frontier "
                         "megabatch core (bitwise-equal, faster batched)")
    # serving knobs
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--queue-depth", type=int, default=256)
    ap.add_argument("--cache-size", type=int, default=1024)
    ap.add_argument("--work-buckets", action="store_true",
                    help="df-predicted admission lanes: coalesce only within "
                         "factor-8 work buckets; heavy queries run alone")
    ap.add_argument("--heavy-df", type=int, default=None,
                    help="summed-df threshold for the batch-1 heavy lane "
                         "(default: 2x the engine's document count)")
    ap.add_argument("--adaptive-wait", action="store_true",
                    help="EWMA inter-arrival tracking: coalescing wait "
                         "drops to 0 while the stream is idle")
    # load shape
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--distinct", type=int, default=64,
                    help="distinct queries in the (Zipf-repeated) workload")
    ap.add_argument("--target-qps", type=float, default=0.0,
                    help="open-loop offered load; 0 = closed loop")
    ap.add_argument("--workers", type=int, default=8,
                    help="closed-loop client concurrency")
    ap.add_argument("--smoke", action="store_true",
                    help="exit 1 unless p99 is finite and nothing was shed")
    # observability
    ap.add_argument("--metrics", action="store_true",
                    help="enable the repro.obs registry (span timelines, "
                         "stage histograms, roofline gauges)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text at /metrics on this port "
                         "(0 = ephemeral; implies --metrics)")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="append a JSONL registry snapshot every S seconds "
                         "(implies --metrics)")
    ap.add_argument("--stats-jsonl", default=None,
                    help="path the periodic/final JSONL snapshots append to "
                         "(default: print to stdout)")
    args = ap.parse_args()

    metrics_on = (args.metrics or args.metrics_port is not None
                  or args.stats_every > 0)
    reg = obs.enable() if metrics_on else None
    metrics_http = None
    if args.metrics_port is not None:
        metrics_http = obs.MetricsServer(reg, port=args.metrics_port)
        print(f"metrics: http://127.0.0.1:{metrics_http.port}/metrics",
              flush=True)

    def emit_snapshot():
        if args.stats_jsonl:
            obs.write_jsonl(args.stats_jsonl, reg)
        else:
            print(obs.snapshot_line(reg), flush=True)

    stats_stop = threading.Event()
    stats_thread = None
    if args.stats_every > 0:
        def _stats_loop():
            while not stats_stop.wait(args.stats_every):
                emit_snapshot()
        stats_thread = threading.Thread(target=_stats_loop, daemon=True,
                                        name="obs-stats-jsonl")

    engine = build_or_load(args)
    print_space_report(engine)
    if args.requests == 0:
        print("no traffic requested (--requests 0); exiting after "
              "build/snapshot")
        return

    if args.mode in ("phrase", "near"):
        # n-grams decoded from the index: positional queries that exercise
        # the matching path, not the empty one (no corpus needed)
        queries = loadgen.sample_ngram_queries(engine, args.distinct,
                                               args.words, seed=args.seed)
    else:
        queries = loadgen.sample_queries(engine, args.distinct, args.words,
                                         seed=args.seed)
    # pin the DRB/OR gather width whenever traffic will ROUTE to drb/or —
    # "auto" routes by the measure's own DR-compatibility, so ask the
    # engine's measure table instead of duplicating the routing rule
    routed_drb = args.mode == "or" and (
        args.strategy == "drb"
        or (args.strategy == "auto"
            and not MEASURES[args.measure].dr_compatible))
    profile = QueryProfile(
        mode=args.mode, strategy=args.strategy, measure=args.measure,
        k=args.k, window=args.window, budget=args.budget,
        beam_width=args.beam_width,
        df_cap=engine.suggested_df_cap(queries) if routed_drb else None,
        mega=True if args.mega else None,
        sla=args.sla, deadline_ms=args.deadline_ms)

    server = SearchServer(engine, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          queue_depth=args.queue_depth,
                          cache_size=args.cache_size,
                          work_buckets=args.work_buckets,
                          heavy_df=args.heavy_df,
                          adaptive_wait=args.adaptive_wait,
                          registry=reg)
    print("warming up (compiling executor buckets) ...", flush=True)
    try:
        n = server.warmup(queries, profile)
    except ValueError as e:       # e.g. BM25 + strategy=dr, budget + drb
        raise SystemExit(f"error: {e}")
    traces0 = sum(engine.stats["traces"].values())
    print(f"compiled {n} executors; admitting traffic", flush=True)

    workload = loadgen.zipf_workload(queries, args.requests, seed=args.seed)
    retry = loadgen.RetryPolicy(max_retries=args.retries, seed=args.seed) \
        if args.retries else loadgen.NO_RETRY
    if stats_thread is not None:
        stats_thread.start()
    with server:
        if args.target_qps > 0:
            rep = loadgen.open_loop(server, workload,
                                    target_qps=args.target_qps,
                                    profile=profile, seed=args.seed,
                                    retry=retry)
        else:
            rep = loadgen.closed_loop(server, workload,
                                      n_workers=args.workers, profile=profile,
                                      retry=retry)
    stats_stop.set()

    retraces = sum(engine.stats["traces"].values()) - traces0
    st = rep.server_stats
    print(rep.summary())
    print(f"batch sizes: {st['batch_hist']} (mean {st['mean_batch']:.2f}) | "
          f"cache hit rate {st['cache']['hit_rate']:.1%} | "
          f"retraces after warmup: {retraces}")
    if metrics_on:
        if rep.stages:
            print("stage latency attribution (registry-derived):")
            for stage, d in sorted(rep.stages.items()):
                print(f"  {stage:10s} p50 {d['p50_ms']:.2f}ms  "
                      f"p95 {d['p95_ms']:.2f}ms  p99 {d['p99_ms']:.2f}ms  "
                      f"(n={d['count']})")
        for g in reg.find("repro_roofline_achieved_frac"):
            be = dict(g.labels).get("backend", "?")
            print(f"roofline[{be}]: achieved fraction {g.value:.2e} of the "
                  "memory-bandwidth floor")
        emit_snapshot()
        if metrics_http is not None:
            metrics_http.close()
    if st["overflowed"]:
        print(f"WARNING: {st['overflowed']} responses hit heap overflow — "
              "their rankings may be incomplete (rebuild with a larger "
              "heap_cap or query a smaller k)")
    if args.smoke:
        # deadline traffic may recompile when the live us/pop estimate
        # drifts across a pow-4 bucket boundary mid-run; the bucketing
        # bounds that to a handful of rungs, never per-request churn
        retrace_ok = retraces == 0 if args.deadline_ms is None \
            else retraces <= 4
        healthy = (np.isfinite(rep.p99_ms) and rep.n_shed == 0
                   and st["errors"] == 0 and retrace_ok
                   and rep.n_timeout == 0
                   and rep.n_ok == args.requests)
        print(f"smoke: {'PASS' if healthy else 'FAIL'}")
        sys.exit(0 if healthy else 1)


if __name__ == "__main__":
    main()
