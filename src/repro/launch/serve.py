"""Serving launcher — the paper's system end-to-end, through `repro.engine`.

Builds a :class:`repro.engine.SearchEngine` over a synthetic corpus (single
index or document-sharded over a local mesh) and serves batched ranked
queries — DR / DRB / auto routing, AND / OR / phrase / near, tf-idf / BM25 —
with latency stats.  All query glue (rank mapping, masking, heap/df caps, jit
executor caching) lives behind ``engine.search``:

  PYTHONPATH=src python -m repro.launch.serve --docs 2000 --queries 100 \
      --strategy dr --mode or --k 10
  PYTHONPATH=src python -m repro.launch.serve --mode near --window 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.engine import SearchEngine
from repro.text import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--mean-doc-len", type=int, default=300)
    ap.add_argument("--vocab", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--words", type=int, default=3)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--strategy", default="auto", choices=("dr", "drb", "auto"))
    ap.add_argument("--mode", default="or",
                    choices=("and", "or", "phrase", "near"))
    ap.add_argument("--measure", default="tfidf", choices=("tfidf", "bm25"))
    ap.add_argument("--budget", type=int, default=None,
                    help="DR any-time pop budget (straggler mitigation)")
    ap.add_argument("--window", type=int, default=None,
                    help="proximity width in tokens (mode=near only)")
    ap.add_argument("--beam-width", type=int, default=None,
                    help="frontier width P of the DR / DRB-AND search loops "
                         "(default 1 = classical one-pop Algorithm 1)")
    ap.add_argument("--shards", type=int, default=0,
                    help="0 = single index; N = document-sharded over a local mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"building corpus: {args.docs} docs ...", flush=True)
    cp = corpus.make_corpus(args.docs, args.mean_doc_len, args.vocab, seed=args.seed)
    if args.shards:
        engine = SearchEngine.shard(cp, n_shards=args.shards)
    else:
        engine = SearchEngine.build(cp)

    if args.mode in ("phrase", "near"):
        # n-grams lifted from the documents: positional queries that exercise
        # the matching path, not the empty one
        queries = corpus.sample_ngram_queries(cp.doc_tokens, args.queries,
                                              args.words, seed=args.seed)
    else:
        df = cp.doc_freqs()
        bands = corpus.fdoc_bands(cp.n_docs)
        queries = corpus.sample_queries(df, bands["ii"], args.queries,
                                        args.words, seed=args.seed)
    run = lambda: engine.search(queries, k=args.k, mode=args.mode,
                                strategy=args.strategy, measure=args.measure,
                                budget=args.budget, window=args.window,
                                beam_width=args.beam_width)

    print("compiling ...", flush=True)
    t0 = time.time()
    try:
        res = run()
    except ValueError as e:          # e.g. BM25 + strategy=dr, budget + drb
        raise SystemExit(f"error: {e}")
    jax.block_until_ready(res.scores)
    compile_s = time.time() - t0
    t0 = time.time()
    res = run()
    jax.block_until_ready(res.scores)
    serve_s = time.time() - t0
    diag = res.diagnostics
    work = int(np.sum(diag["work"]))
    extra = (f" | pops {int(np.sum(diag['pops']))}" if "pops" in diag else "")
    if bool(np.any(diag.get("overflowed", False))):
        extra += " | WARNING: heap overflow — rankings may be incomplete"
    print(f"compile {compile_s:.1f}s | {args.queries} queries in {serve_s*1e3:.1f}ms "
          f"({serve_s/args.queries*1e3:.2f} ms/query) | routed to {res.strategy} "
          f"| beam {res.beam_width} | loop trips {work}{extra}")
    print("first query top-k docs:", np.asarray(res.docs[0])[:args.k].tolist())
    if res.match_pos is not None:
        print("first query matches (doc, score, pos, len):", res.matches(0))


if __name__ == "__main__":
    main()
