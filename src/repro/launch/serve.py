"""Serving launcher — the paper's system end-to-end.

Builds a (sharded) WTBC index over a synthetic corpus, then serves batched
ranked queries (DR / DRB, AND / OR, tf-idf / BM25) with latency stats:

  PYTHONPATH=src python -m repro.launch.serve --docs 2000 --queries 100 \
      --method dr-or --k 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, drb, ranked, scoring, wtbc
from repro.text import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--mean-doc-len", type=int, default=300)
    ap.add_argument("--vocab", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--words", type=int, default=3)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--method", default="dr-or",
                    choices=("dr-and", "dr-or", "drb-and", "drb-or"))
    ap.add_argument("--measure", default="tfidf", choices=("tfidf", "bm25"))
    ap.add_argument("--shards", type=int, default=0,
                    help="0 = single index; N = document-sharded over a local mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"building corpus: {args.docs} docs ...", flush=True)
    cp = corpus.make_corpus(args.docs, args.mean_doc_len, args.vocab, seed=args.seed)
    measure = scoring.BM25() if args.measure == "bm25" else scoring.TfIdf()

    df = cp.doc_freqs()
    bands = corpus.fdoc_bands(cp.n_docs)
    queries = corpus.sample_queries(df, bands["ii"], args.queries, args.words,
                                    seed=args.seed)

    if args.shards:
        sharded, model = distributed.build_sharded(cp.doc_tokens, cp.vocab_size,
                                                   n_shards=args.shards)
        mesh = jax.make_mesh((args.shards,), ("shards",))
        qw = jnp.asarray(model.rank_of_word[queries], jnp.int32)
        wmask = jnp.ones_like(qw, dtype=bool)
        run = lambda: distributed.distributed_topk(
            sharded, qw, wmask, k=args.k, method=args.method, mesh=mesh,
            shard_axes="shards", measure=measure,
            max_df_cap=int(df.max()) + 2)
    else:
        idx, model = wtbc.build_index(cp.doc_tokens, cp.vocab_size)
        aux = drb.build_aux(idx, model, cp.doc_tokens)
        idf = measure.idf(idx)
        qw = jnp.asarray(model.rank_of_word[queries], jnp.int32)
        wmask = jnp.ones_like(qw, dtype=bool)
        conj = args.method.endswith("and")
        if args.method.startswith("dr"):
            if args.measure == "bm25":
                raise SystemExit("BM25 requires DRB (paper §5); use --method drb-*")
            heap_cap = 2 * int(idx.n_docs) + 4
            run = lambda: ranked.topk_dr_batch(idx, qw, wmask, idf, k=args.k,
                                               conjunctive=conj, heap_cap=heap_cap)
        else:
            fn = drb.topk_drb_and if conj else drb.topk_drb_or
            kw = {} if conj else {"max_df_cap": int(df.max()) + 2}
            run = lambda: jax.vmap(
                lambda w, m: fn(idx, aux, w, m, measure, k=args.k, **kw))(qw, wmask)

    print("compiling ...", flush=True)
    t0 = time.time()
    res = jax.block_until_ready(run())
    compile_s = time.time() - t0
    t0 = time.time()
    res = jax.block_until_ready(run())
    serve_s = time.time() - t0
    docs = np.asarray(res.docs if hasattr(res, "docs") else res[0])
    print(f"compile {compile_s:.1f}s | {args.queries} queries in {serve_s*1e3:.1f}ms "
          f"({serve_s/args.queries*1e3:.2f} ms/query)")
    print("first query top-k docs:", docs[0][:args.k].tolist())


if __name__ == "__main__":
    main()
