import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process.

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production meshes, and record memory/cost/collective evidence.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --mesh single          # 16x16
#   PYTHONPATH=src python -m repro.launch.dryrun --mesh multi           # 2x16x16
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
#
# Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json (idempotent: existing
# artifacts are skipped unless --force).  EXPERIMENTS.md §Dry-run and the
# roofline analysis read these files.
# (module docstring kept as a comment: the XLA_FLAGS lines above must be the
#  first statements in the file.)

import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base, registry
from repro.configs import wtbc_paper
from repro.launch import mesh as mesh_lib
from repro.optim import adamw

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

KIND_ARGS = {
    "train": ("batch",),
    "prefill": ("tokens",),
    "decode": ("caches", "tokens", "cache_len"),
    "serve": ("batch",),
    "retrieval": ("batch",),
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _tensor_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        d = d.strip()
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo: str) -> dict:
    """Per-device wire-byte estimate per collective kind.

    Model (ring algorithms): all-reduce moves 2x payload; gather/scatter/
    permute/all-to-all move ~1x.  Payload per op = largest tensor named on
    the op's line (robust to tuple-typed async starts).  `-done` halves of
    async pairs are skipped.
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hit = None
        for k in COLLECTIVES:
            if re.search(rf"(?:^|[ (]){k}(?:-start)?\(", s):
                hit = k
                break
        if hit is None or f"{hit}-done" in s:
            continue
        sizes = [_tensor_bytes(t, d)
                 for t, d in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", s)]
        payload = max(sizes, default=0)
        mult = 2 if hit == "all-reduce" else 1
        out[hit]["count"] += 1
        out[hit]["bytes"] += mult * payload
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _mem_dict(mem) -> dict:
    if mem is None:
        return {"unavailable": True}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    d = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            d[k] = int(v)
    return d or {"repr": str(mem)}


def _shardings(mesh, tree):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch, cell: base.Cell, mesh, mesh_name: str,
               cfg_override=None) -> dict:
    t0 = time.time()
    rules = base.make_rules(mesh.axis_names, cell)
    rec = {"cell": cell.cell_id, "kind": cell.kind, "mesh": mesh_name,
           "mesh_shape": dict(zip(mesh.axis_names,
                                  np.array(mesh.devices.shape).tolist())),
           "rules": {k: v for k, v in rules.rules}}

    if arch.name == "wtbc":
        cfg = arch.config()
        sharded_abs = wtbc_paper.abstract_sharded(cfg, mesh.size)
        fn = arch.make_query_fn(cfg, cell.shape, mesh, tuple(mesh.axis_names))
        inputs = arch.abstract_inputs(cfg, cell.shape)
        in_specs = (arch.sharded_specs(sharded_abs, tuple(mesh.axis_names)),
                    P(), P())
        args = (sharded_abs, inputs["words"], inputs["wmask"])
        jitted = jax.jit(fn, in_shardings=_shardings(mesh, in_specs))
    else:
        cfg = arch.config_for(cell.shape) if hasattr(arch, "config_for") \
            else arch.config()
        if cfg_override is not None:
            cfg = cfg_override(cfg)
        step = arch.make_step(cfg, cell.kind, rules)
        pspecs = arch.param_specs(cfg, rules)
        params_abs = arch.abstract_params(cfg)
        inputs_abs = arch.abstract_inputs(cfg, cell.shape)
        input_specs = arch.input_specs(cfg, cell.shape, rules)
        arg_names = KIND_ARGS[cell.kind]
        args = [params_abs] + [inputs_abs[n] for n in arg_names]
        specs = [pspecs] + [input_specs[n] for n in arg_names]
        if cell.kind == "train":
            opt_abs = jax.eval_shape(adamw.init_state, params_abs)
            ospecs = adamw.state_specs(pspecs)
            args.insert(1, opt_abs)
            specs.insert(1, ospecs)
        rec["flops_note"] = arch.flops_note(cfg)
        jitted = jax.jit(step, in_shardings=tuple(
            _shardings(mesh, s) for s in specs))
        args = tuple(args)

    with mesh:
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        pass
    hlo = compiled.as_text()

    rec.update({
        "ok": True,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if isinstance(v, (int, float)) and np.isfinite(v)},
        "memory_analysis": _mem_dict(mem),
        "collectives": parse_collectives(hlo),
        "hlo_bytes": len(hlo),
    })
    return rec


def probe_groups(mesh_name: str, arch_filter: str | None = None,
                 shape_filter: str | None = None) -> None:
    """Two-point scan-trip probe for LM cells (XLA cost analysis counts a
    ``scan`` body once; compiling with 1 and 2 layer groups lets the roofline
    extrapolate exact totals: total = m1 + (G-1)·(m2-m1))."""
    import dataclasses as dc
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    outdir = ART / mesh_name
    outdir.mkdir(parents=True, exist_ok=True)
    for cell in registry.all_cells(include_paper=False):
        arch = registry.get(cell.arch)
        if arch.family != "lm" or cell.skip:
            continue
        if arch_filter and cell.arch != arch_filter:
            continue
        if shape_filter and cell.shape != shape_filter:
            continue
        path = outdir / f"{cell.arch}__{cell.shape}.json"
        if not path.exists():
            continue
        rec = json.loads(path.read_text())
        if "probe_g1" in rec and "probe_g2" in rec:
            continue
        print(f"[probe] {cell.cell_id} on {mesh_name}", flush=True)
        try:
            for g in (1, 2):
                def override(cfg, g=g):
                    return dc.replace(cfg, n_layers=len(cfg.pattern) * g)
                sub = lower_cell(arch, cell, mesh, mesh_name, cfg_override=override)
                rec[f"probe_g{g}"] = {
                    "cost_analysis": sub["cost_analysis"],
                    "collectives": sub["collectives"],
                    "memory_analysis": sub["memory_analysis"],
                }
            cfg = arch.config_for(cell.shape)
            rec["n_groups"] = cfg.n_groups
            path.write_text(json.dumps(rec, indent=1))
            print("  probe ok", flush=True)
        except Exception as e:
            print(f"  probe FAILED: {type(e).__name__}: {str(e)[:200]}", flush=True)


def run(mesh_name: str, arch_filter: str | None, shape_filter: str | None,
        force: bool, include_paper: bool = True) -> int:
    multi = mesh_name == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    outdir = ART / mesh_name
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for cell in registry.all_cells(include_paper=include_paper):
        if arch_filter and cell.arch != arch_filter:
            continue
        if shape_filter and cell.shape != shape_filter:
            continue
        path = outdir / f"{cell.arch}__{cell.shape}.json"
        if path.exists() and not force:
            print(f"[skip-cached] {cell.cell_id}")
            continue
        arch = registry.get(cell.arch)
        if cell.skip:
            rec = {"cell": cell.cell_id, "mesh": mesh_name, "ok": True,
                   "skipped": cell.skip}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[skip-by-design] {cell.cell_id}: {cell.skip}")
            continue
        print(f"[lower+compile] {cell.cell_id} on {mesh_name} ...", flush=True)
        try:
            rec = lower_cell(arch, cell, mesh, mesh_name)
            ca = rec["cost_analysis"]
            print(f"  ok: compile={rec['compile_s']}s "
                  f"flops={ca.get('flops', float('nan')):.3e} "
                  f"coll={rec['collectives']['total_bytes']:.3e}B", flush=True)
        except Exception as e:
            failures += 1
            rec = {"cell": cell.cell_id, "mesh": mesh_name, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"  FAILED: {type(e).__name__}: {str(e)[:300]}", flush=True)
        path.write_text(json.dumps(rec, indent=1))
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-paper", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="two-point scan-trip probe for LM cells")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for m in meshes:
        if args.probe:
            probe_groups(m, args.arch, args.shape)
        else:
            failures += run(m, args.arch, args.shape, args.force,
                            include_paper=not args.no_paper)
    print(f"dry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
