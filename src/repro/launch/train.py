"""Training launcher: --arch <id> [--smoke] [--steps N] [--resume].

On this CPU container the practical path is ``--smoke`` (reduced config,
local mesh); the same code drives the production mesh on real hardware —
the mesh/sharding wiring is identical to dryrun.py, just with concrete
arrays instead of ShapeDtypeStructs.

Fault tolerance is on by default: async checkpoints every --ckpt-every
steps, restore-on-start when --resume, deterministic counter->batch data
(runtime/fault_tolerance.py proves restart continuity in tests).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base, registry
from repro.configs.base import make_rules
from repro.checkpoint import ckpt as ckpt_lib
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerWatchdog


def make_batch_fn(arch, cfg, batch: int, seq: int, seed: int):
    fam = arch.family
    if fam == "lm":
        return lambda step: pipeline.lm_batch(seed, step, batch, seq, cfg.vocab)
    if fam == "recsys":
        return lambda step: pipeline.recsys_batch(seed, step, batch, cfg)
    if fam == "gnn":
        g = pipeline.random_graph(seed, n_nodes=512, n_edges=2048,
                                  d_feat=cfg.d_feat, n_classes=cfg.n_classes)
        return lambda step: g
    raise ValueError(fam)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = arch.config(smoke=args.smoke)
    mesh = mesh_lib.make_local_mesh()
    rules = make_rules(mesh.axis_names)
    step_fn = jax.jit(arch.make_step(cfg, "train", rules))

    key = jax.random.PRNGKey(args.seed)
    params = arch.init_params(key, cfg)
    opt = adamw.init_state(params)
    start = 0
    ckpt_dir = f"{args.ckpt_dir}/{args.arch}"
    if args.resume and ckpt_lib.list_steps(ckpt_dir):
        (state, start) = ckpt_lib.restore(ckpt_dir, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    batch_fn = make_batch_fn(arch, cfg, args.batch, args.seq, args.seed)
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    wd = StragglerWatchdog()
    with mesh:
        for step in range(start, args.steps):
            t0 = time.time()
            params, opt, metrics = step_fn(params, opt, batch_fn(step))
            dt = time.time() - t0
            slow = wd.observe(step, dt)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.1f}ms{'  [straggler]' if slow else ''}",
                      flush=True)
            if (step + 1) % args.ckpt_every == 0:
                saver.save_async(step + 1, {"params": params, "opt": opt})
    saver.wait()
    ckpt_lib.save(ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
