"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape), single-pod mesh, TPU v5e constants:

  compute_s    = HLO_FLOPs_per_chip / 197e12        (bf16 MXU peak)
  memory_s     = HLO_bytes_per_chip / 819e9          (HBM)
  collective_s = collective_bytes_per_chip / 50e9    (ICI link)

HLO numbers come from ``compiled.cost_analysis()`` / the HLO-text collective
parser on the SPMD-partitioned per-device module.  XLA counts a ``lax.scan``
body ONCE, so LM cells carry a two-point probe (G=1 and G=2 layer groups);
the exact per-device total is the linear extrapolation
``m1 + (n_groups - 1) * (m2 - m1)`` (layer groups are homogeneous by
construction).  Cells without scans (recsys/gnn) need no correction.  The
WTBC cells' while-loops are data-dependent: the analysis reports
per-candidate-iteration cost x the expected iteration count.

MODEL_FLOPS (the "useful work" numerator for the compute-fraction score) is
analytic: 6·N·T for dense-LM training (6·N_active·T for MoE) plus exact
attention-window terms, 2·N·T for inference; per-tower closed forms for
recsys; per-layer closed forms for EGNN.

**WTBC query-path model** (ISSUE 8, DESIGN.md §9): the search loop is pure
memory traffic — every rank probe reads one counter-block tile plus a
counter entry, and Algorithm 1 issues ``2 ranks × levels × Q`` probes per
popped (or padded) beam lane.  ``wtbc_query_roofline`` turns measured
pops/padded/latency into bytes/query and an achieved-fraction-of-peak
against the backend's memory bandwidth — the number benchmarks/table5 and
BENCH_PR8.json report next to each beam cell.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def _lm_attn_flops(cfg, B, S, decode=False):
    """Sum over layers of QK^T + PV flops (fwd)."""
    total = 0.0
    for i, pat in enumerate(cfg.pattern):
        n = cfg.n_layers // len(cfg.pattern)
        if decode:
            span = S if pat == "global" or cfg.window == 0 else min(cfg.window, S)
            total += n * 4.0 * B * span * cfg.n_heads * cfg.head_dim
        else:
            if pat == "global" or cfg.window == 0 or cfg.window >= S:
                span = S / 2
            elif pat == "local":
                span = cfg.window
            else:                       # chunked: average window/2
                span = cfg.window / 2
            total += n * 4.0 * B * S * span * cfg.n_heads * cfg.head_dim
    return total


def lm_model_flops(cfg, shape_meta: dict, kind: str) -> float:
    B, S = shape_meta["batch"], shape_meta["seq"]
    N_act = cfg.active_param_count()
    if kind == "train":
        T = B * S
        fwd = 2.0 * N_act * T + _lm_attn_flops(cfg, B, S)
        factor = 3.0 + (1.0 if cfg.remat else 0.0)   # fwd+2bwd (+refwd remat)
        return factor * fwd
    if kind == "prefill":
        return 2.0 * N_act * B * S + _lm_attn_flops(cfg, B, S)
    # decode: one token, full KV span
    return 2.0 * N_act * B + _lm_attn_flops(cfg, B, S, decode=True)


def recsys_model_flops(cfg, B: int, kind: str) -> float:
    d = cfg.embed_dim
    f = 0.0
    if cfg.interaction == "fm":
        f = 4.0 * B * cfg.n_sparse * d
    elif cfg.interaction == "cin":
        dims = (cfg.n_sparse,) + cfg.cin_layers
        for i in range(len(cfg.cin_layers)):
            f += 2.0 * B * dims[i + 1] * dims[i] * cfg.n_sparse * d \
                 + 2.0 * B * dims[i] * cfg.n_sparse * d
        flat = cfg.n_sparse * d
        f += 2.0 * B * (flat * 400 + 400 * 400 + flat)
    elif cfg.interaction == "dot":
        dims = (cfg.n_dense,) + cfg.bot_mlp
        f += 2.0 * B * sum(a * b for a, b in zip(dims[:-1], dims[1:]))
        nf = cfg.n_sparse + 1
        f += 2.0 * B * nf * nf * d
        n_inter = nf * (nf - 1) // 2
        tdims = (cfg.bot_mlp[-1] + n_inter,) + cfg.top_mlp
        f += 2.0 * B * sum(a * b for a, b in zip(tdims[:-1], tdims[1:]))
    elif cfg.interaction == "self-attn-seq":
        S = cfg.seq_len
        per_blk = 2.0 * B * S * d * d * 6 + 4.0 * B * S * S * d / 2
        f = cfg.n_blocks * per_blk
    if kind == "train":
        f *= 3.0
    return f


def egnn_model_flops(cfg, n_nodes: int, n_edges: int, kind: str) -> float:
    H = cfg.d_hidden
    per_layer = (2.0 * n_edges * ((2 * H + 1) * H + H * H)      # phi_e
                 + 2.0 * n_edges * (H * H + H)                  # phi_x
                 + 2.0 * n_nodes * (2 * H * H + H * H))         # phi_h
    f = cfg.n_layers * per_layer + 2.0 * n_nodes * cfg.d_feat * H
    return f * (3.0 if kind == "train" else 1.0)


# ---------------------------------------------------------------------------
# artifact reduction
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellRoofline:
    cell: str
    kind: str
    chips: int
    hlo_flops: float             # per-chip, scan-corrected (XLA-CPU caveat:
                                 # oneDNN custom-call matmuls report 0 flops,
                                 # so this UNDERCOUNTS — reported for trend
                                 # tracking only)
    bytes_hbm: float             # per-chip, scan-corrected
    coll_bytes: float            # per-chip, scan-corrected
    compute_s: float             # analytic MODEL_FLOPS / chip / peak
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_chip: float
    peak_mem_gb: float | None
    skipped: str | None = None

    def step_time(self) -> float:
        """No-overlap upper bound (the three terms serialized)."""
        return self.compute_s + self.memory_s + self.collective_s

    def roofline_fraction(self) -> float:
        """useful-compute share of the binding resource:
        compute_s / max(compute_s, memory_s, collective_s).
        1.0 = the cell is bound by useful MXU work (at roofline); lower
        values = memory or collective time exceeds useful compute."""
        m = max(self.compute_s, self.memory_s, self.collective_s, 1e-30)
        return self.compute_s / m


def _probe_total(rec: dict, metric_path, n_groups: int) -> float | None:
    try:
        m1 = metric_path(rec["probe_g1"])
        m2 = metric_path(rec["probe_g2"])
    except KeyError:
        return None
    return m1 + (n_groups - 1) * (m2 - m1)


def reduce_cell(rec: dict, model_flops_total: float | None) -> CellRoofline:
    if rec.get("skipped"):
        return CellRoofline(cell=rec["cell"], kind="-", chips=0, hlo_flops=0,
                            bytes_hbm=0, coll_bytes=0, compute_s=0, memory_s=0,
                            collective_s=0, dominant="-",
                            model_flops_per_chip=0, peak_mem_gb=None,
                            skipped=rec["skipped"])
    chips = int(np.prod(list(rec["mesh_shape"].values())))
    G = rec.get("n_groups")
    flops = rec["cost_analysis"].get("flops", 0.0)
    hbm = rec["cost_analysis"].get("bytes accessed", 0.0)
    coll = rec["collectives"]["total_bytes"]
    if G and "probe_g1" in rec:
        flops = _probe_total(rec, lambda p: p["cost_analysis"].get("flops", 0.0), G) or flops
        hbm = _probe_total(rec, lambda p: p["cost_analysis"].get("bytes accessed", 0.0), G) or hbm
        coll = _probe_total(rec, lambda p: p["collectives"]["total_bytes"], G) or coll
    mf = (model_flops_total or 0.0) / chips
    flops, hbm, coll = max(flops, 0.0), max(hbm, 0.0), max(coll, 0.0)  # probe
    # extrapolation can go slightly negative when XLA CSEs across group counts
    compute_s = max(mf, flops) / PEAK_FLOPS_BF16   # analytic useful compute
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])[0]
    peak = rec.get("memory_analysis", {}).get("peak_memory_in_bytes")
    return CellRoofline(
        cell=rec["cell"], kind=rec.get("kind", "?"), chips=chips,
        hlo_flops=flops, bytes_hbm=hbm, coll_bytes=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom, model_flops_per_chip=mf,
        peak_mem_gb=(peak / 2**30 if peak else None))


def model_flops_for(cell_id: str, kind: str) -> float | None:
    from repro.configs import registry
    from repro.configs.lm_common import LM_SHAPES
    from repro.configs import recsys_common, egnn as egnn_cfg
    arch_name, shape = cell_id.split(":")
    if arch_name == "wtbc":
        return None
    arch = registry.get(arch_name)
    cfg = arch.config_for(shape)
    if arch.family == "lm":
        return lm_model_flops(cfg, LM_SHAPES[shape], kind)
    if arch.family == "recsys":
        if shape == "retrieval_cand":
            return recsys_model_flops(cfg, recsys_common.N_CANDIDATES, "serve")
        B = recsys_common.SHAPES[shape]["batch"]
        return recsys_model_flops(cfg, B, kind)
    if arch.family == "gnn":
        m = egnn_cfg.SHAPES[shape]
        return egnn_model_flops(cfg, m["nodes"], m["edges"], kind)
    return None


def load_all(mesh_name: str = "single") -> list[CellRoofline]:
    out = []
    for path in sorted((ART / mesh_name).glob("*.json")):
        rec = json.loads(path.read_text())
        if not rec.get("ok"):
            continue
        kind = rec.get("kind", "?")
        mf = model_flops_for(rec["cell"], kind) if ":" in rec["cell"] else None
        out.append(reduce_cell(rec, mf))
    return out


def markdown_table(rows: list[CellRoofline]) -> str:
    hdr = ("| cell | kind | compute_s | memory_s | collective_s | dominant | "
           "roofline frac | peak GB |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.skipped:
            lines.append(f"| {r.cell} | skip | - | - | - | - | - | - |")
            continue
        lines.append(
            f"| {r.cell} | {r.kind} | {r.compute_s:.2e} | {r.memory_s:.2e} | "
            f"{r.collective_s:.2e} | **{r.dominant}** | "
            f"{r.roofline_fraction():.3f} | "
            f"{'' if r.peak_mem_gb is None else f'{r.peak_mem_gb:.1f}'} |")
    return hdr + "\n".join(lines)


# ---------------------------------------------------------------------------
# WTBC query-path roofline (DESIGN.md §9)
# ---------------------------------------------------------------------------

# Memory bandwidth floor per canonical kernel backend.  The TPU number is the
# v5e HBM constant the training-cell roofline above already uses; the GPU
# number is an A100-class 2 TB/s; "cpu" is a DDR5-ish 41 GB/s single-socket
# stream bandwidth — deliberately conservative so the achieved fraction on the
# CI interpret path reads as an upper bound, not a brag.
WTBC_MEM_BW: dict[str, float] = {
    "tpu": HBM_BW,
    "gpu": 2.0e12,
    "cpu": 4.1e10,
}

# Per-rank counter traffic: the TPU lowering DMAs the whole (1, 256) int32
# superblock counter row next to each tile; the GPU/ref lowerings gather one
# 4-byte entry.
WTBC_COUNTER_BYTES: dict[str, float] = {"tpu": 256 * 4.0, "gpu": 4.0,
                                        "cpu": 4.0}


def wtbc_query_bytes(*, pops: float, padded: float, q: int, block: int,
                     levels: int = 3,
                     counter_bytes: float = 4.0) -> float:
    """Bytes the WTBC query path must move per query.

    Every popped beam lane (plus every padded dead lane — the hardware reads
    for those too, which is exactly why table5 tracks pad waste) descends all
    ``levels`` of the wavelet tree for each of the ``q`` query words, and each
    level's ``count_range`` issues 2 rank probes.  A probe touches one
    ``block``-byte counter-block tile plus ``counter_bytes`` of superblock
    counters; the tiny node-offset/codeword tables are shared across probes
    and amortize to ~0.
    """
    ranks = 2.0 * levels * q * (pops + padded)
    return ranks * (block + counter_bytes)


@dataclasses.dataclass
class WTBCQueryRoofline:
    """Memory-roofline attachment for one table5 beam cell."""
    backend: str                  # canonical kernel backend the BW came from
    bytes_per_query: float
    model_us_per_query: float     # bytes / BW — the memory-bound floor
    measured_us_per_query: float
    achieved_frac: float          # model / measured; 1.0 = at the roofline,
                                  # small values = launch/loop overhead bound


def wtbc_query_roofline(*, backend: str, measured_us_per_query: float,
                        pops: float, padded: float, q: int, block: int,
                        levels: int = 3) -> WTBCQueryRoofline:
    """Attach the bytes/query model to a measured per-query latency.

    ``pops``/``padded`` are per-query means (floats are fine); ``backend`` is
    ``kernels.backend.canonical_backend()`` — it picks both the bandwidth
    floor and the counter-traffic shape.
    """
    cb = WTBC_COUNTER_BYTES.get(backend, 4.0)
    bpq = wtbc_query_bytes(pops=pops, padded=padded, q=q, block=block,
                           levels=levels, counter_bytes=cb)
    bw = WTBC_MEM_BW.get(backend, WTBC_MEM_BW["cpu"])
    model_us = bpq / bw * 1e6
    frac = model_us / max(measured_us_per_query, 1e-9)
    return WTBCQueryRoofline(backend=backend, bytes_per_query=bpq,
                             model_us_per_query=model_us,
                             measured_us_per_query=measured_us_per_query,
                             achieved_frac=frac)


def live_wtbc_gauges(rl: WTBCQueryRoofline, reg=None) -> None:
    """Export one measured WTBC query roofline into a :mod:`repro.obs`
    registry as live gauges (labeled by kernel backend) — the production
    attachment: the engine facade calls this after each observed search, so
    a scrape of ``/metrics`` always shows the current bytes/query model and
    achieved fraction next to the serving counters (DESIGN.md §10)."""
    import repro.obs as obs
    reg = obs.resolve(reg)
    labels = {"backend": rl.backend}
    reg.gauge("repro_roofline_bytes_per_query", labels,
              "modelled WTBC bytes moved per query").set(rl.bytes_per_query)
    reg.gauge("repro_roofline_model_us_per_query", labels,
              "memory-bound latency floor (us/query)"
              ).set(rl.model_us_per_query)
    reg.gauge("repro_roofline_measured_us_per_query", labels,
              "measured latency (us/query)").set(rl.measured_us_per_query)
    reg.gauge("repro_roofline_achieved_frac", labels,
              "model floor / measured (1.0 = at the memory roofline)"
              ).set(rl.achieved_frac)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(args.mesh)
    print(markdown_table(rows))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(json.dumps(
            [dataclasses.asdict(r) for r in rows], indent=1))


if __name__ == "__main__":
    main()
